#!/usr/bin/env bash
# Repo gate: formatting, lints, wire compat, tests.  Run from anywhere.
#
#   scripts/check.sh           # fmt + clippy + analyze + wire-compat
#                              # + test + bench compile
#   scripts/check.sh --bench   # ...then the headline serving bench,
#                              # which writes BENCH_serving.json
#                              # (p50/p95 latency, req/s, steps/s,
#                              # stream_overhead_pct, and the predictor
#                              # scenario: prediction MAE + goodput
#                              # under deadlines, predictor on vs off)
#
# The bench-schema stage validates BENCH_serving.json's top-level keys
# against scripts/bench_schema.txt (host_bytes_per_step,
# stream_overhead_pct, frozen_step_fraction, ...), so a scenario
# refactor can't silently drop a trendline field; it skips with a
# message when no BENCH_serving.json has been written yet.
#
# The analyze stage runs the in-tree architectural lint
# (`repro analyze --deny`): serving-path panic-freedom, the
# match-on-family seal, the metrics key registry, envelope-field vs
# API.md drift, unsafe-SAFETY hygiene, and the lock-nesting-order
# check.  Any unannotated violation
# fails the gate; suppressions must be justified
# `// lint:allow(<check>): <reason>` lines (see API.md).
#
# The wire-compat stage runs the golden-corpus / envelope round-trip
# tests explicitly (they are pure codec tests, so they run even where
# artifacts are absent) — the legacy JSON-lines protocol is a
# compatibility contract and breaking it must fail loudly, not hide in
# the big test run.  The chaos stage runs the seeded fault-injection /
# crash-recovery / brownout suite explicitly for the same reason.
# `cargo bench --no-run` is part of the default
# gate so bench targets (including the mixed-family and streaming
# serving scenarios) can never rot uncompiled.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== repro analyze (architectural lint, zero unannotated violations) =="
cargo run -q -- analyze --deny

echo "== wire compat (golden legacy corpus + envelope round-trips) =="
cargo test -q --test wire_compat

echo "== cargo test -q =="
cargo test -q

echo "== chaos (seeded fault schedules, crash recovery, brownout) =="
cargo test -q --test chaos_stress

echo "== cargo bench --no-run (bench targets must keep compiling) =="
cargo bench --no-run

if [[ "${1:-}" == "--bench" ]]; then
  echo "== serving bench (writes BENCH_serving.json) =="
  cargo bench --bench serving_bench
fi

echo "== bench schema (BENCH_serving.json top-level keys) =="
if [[ -f BENCH_serving.json ]]; then
  missing=0
  while IFS= read -r key; do
    [[ -z "$key" || "$key" == \#* ]] && continue
    if ! grep -q "\"$key\":" BENCH_serving.json; then
      echo "bench-schema: BENCH_serving.json is missing \"$key\""
      missing=1
    fi
  done < scripts/bench_schema.txt
  [[ "$missing" == 0 ]] || exit 1
else
  echo "bench-schema: no BENCH_serving.json — skipping (run with --bench)"
fi

echo "check.sh: all green"
