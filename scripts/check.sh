#!/usr/bin/env bash
# Repo gate: formatting, lints, tests.  Run from anywhere.
#
#   scripts/check.sh           # fmt + clippy + test + bench compile
#   scripts/check.sh --bench   # ...then the headline serving bench,
#                              # which writes BENCH_serving.json
#                              # (p50/p95 latency, req/s, steps/s)
#
# `cargo bench --no-run` is part of the default gate so bench targets
# (including the mixed-family serving scenario) can never rot
# uncompiled even where artifacts are absent.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run (bench targets must keep compiling) =="
cargo bench --no-run

if [[ "${1:-}" == "--bench" ]]; then
  echo "== serving bench (writes BENCH_serving.json) =="
  cargo bench --bench serving_bench
fi

echo "check.sh: all green"
