#!/usr/bin/env bash
# Repo gate: formatting, lints, tests.  Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo test -q =="
cargo test -q

echo "check.sh: all green"
