//! Training driver: runs the `<family>_train_b16_l64` artifacts (Adam is
//! fused into the artifact) with rust owning the loop, data pipeline,
//! learning-rate schedule, loss log and checkpoints.
//!
//! This is how every model in the repo is trained — the DDLM (with its
//! masking × t_max × time-warping ablation grid, Tables 4-7), the SSD and
//! Plaid baselines, and the AR evaluator that computes AR-NLL.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::corpus::dataset::{Dataset, Masking};
use crate::log_info;
use crate::models::store::{OptState, ParamStore};
use crate::runtime::{Executable, Runtime, Tensor};
use crate::sampler::Family;
use crate::util::prng::Prng;

/// Which model a trainer drives ("ar" is the evaluator, not a DLM family).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainTarget {
    Dlm(Family),
    Ar,
}

impl TrainTarget {
    pub fn family_name(&self) -> &'static str {
        match self {
            TrainTarget::Dlm(f) => f.name(),
            TrainTarget::Ar => "ar",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub target: TrainTarget,
    pub steps: usize,
    pub base_lr: f32,
    pub warmup: usize,
    pub masking: Masking,
    /// DDLM ablation knobs (ignored by other targets)
    pub t_max: f32,
    pub time_warping: bool,
    pub seed: u64,
    pub log_every: usize,
}

impl TrainConfig {
    pub fn new(target: TrainTarget, steps: usize) -> TrainConfig {
        TrainConfig {
            target,
            steps,
            base_lr: 3e-3,
            warmup: 50,
            masking: Masking::Mlm,
            t_max: 10.0,
            time_warping: true,
            seed: 42,
            log_every: 50,
        }
    }

    /// Cosine schedule with linear warmup (paper Table 2 uses the same
    /// family of schedule at its own scale).
    pub fn lr_at(&self, step: usize) -> f32 {
        if step < self.warmup {
            return self.base_lr * (step + 1) as f32 / self.warmup as f32;
        }
        let p = (step - self.warmup) as f32
            / (self.steps.saturating_sub(self.warmup)).max(1) as f32;
        0.5 * self.base_lr * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

pub struct Trainer {
    pub cfg: TrainConfig,
    exe: Rc<Executable>,
    pub store: ParamStore,
    opt: OptState,
    dataset: Dataset,
    rng: Prng,
    pub step: usize,
    pub losses: Vec<f32>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
    d_model: usize,
}

impl Trainer {
    pub fn new(rt: &Runtime, cfg: TrainConfig) -> Result<Trainer> {
        let m = &rt.manifest.model;
        let fam = cfg.target.family_name();
        let name = format!("{fam}_train_b16_l{}", m.seq_len);
        let exe = rt.executable(&name)?;
        let store = ParamStore::load_init(
            rt.manifest.dir.to_str().unwrap(),
            fam,
        )?;
        let opt = OptState::zeros_like(&store);
        let dataset = Dataset::new(m.vocab, m.seq_len);
        let rng = Prng::new(cfg.seed).fork("train");
        Ok(Trainer {
            batch: exe.spec.batch,
            seq_len: m.seq_len,
            vocab: m.vocab,
            d_model: m.d_model,
            cfg,
            exe,
            store,
            opt,
            dataset,
            rng,
            step: 0,
            losses: Vec::new(),
        })
    }

    /// Resume from a checkpoint (optimizer state restarts at zero — fine
    /// for the experiment scales here; documented simplification).
    pub fn with_params(mut self, store: ParamStore) -> Trainer {
        self.opt = OptState::zeros_like(&store);
        self.store = store;
        self
    }

    /// One training step: sample a batch, run the artifact, absorb the new
    /// parameters/optimizer state.  Returns the step loss (CE, nats).
    pub fn train_step(&mut self) -> Result<f32> {
        let (b, l) = (self.batch, self.seq_len);
        let batch = self.dataset.train_batch(&mut self.rng, b, self.cfg.masking);
        let lr = self.cfg.lr_at(self.step);

        let mut data: BTreeMap<String, Tensor> = BTreeMap::new();
        // optimizer state + counter
        for (k, t) in &self.opt.m {
            data.insert(format!("m.{k}"), t.clone());
        }
        for (k, t) in &self.opt.v {
            data.insert(format!("v.{k}"), t.clone());
        }
        data.insert("count".into(), Tensor::scalar_f32(self.opt.count));
        data.insert("tokens".into(), Tensor::i32(&[b, l], batch.tokens));
        data.insert("lr".into(), Tensor::scalar_f32(lr));

        match self.cfg.target {
            TrainTarget::Ar => {}
            TrainTarget::Dlm(fam) => {
                data.insert("mask".into(), Tensor::f32(&[b, l], batch.mask));
                let u: Vec<f32> =
                    (0..b).map(|_| self.rng.uniform_f32()).collect();
                data.insert("u".into(), Tensor::f32(&[b], u));
                // offline loss-graph construction, not serving dispatch
                // lint:allow(family-seal): training builds per-family noise inputs
                match fam {
                    Family::Ddlm => {
                        let eps =
                            self.rng.gaussian_vec_f32(b * l * self.d_model);
                        data.insert(
                            "eps".into(),
                            Tensor::f32(&[b, l, self.d_model], eps),
                        );
                        data.insert(
                            "t_max".into(),
                            Tensor::scalar_f32(self.cfg.t_max),
                        );
                        data.insert(
                            "tw_flag".into(),
                            Tensor::scalar_f32(if self.cfg.time_warping {
                                1.0
                            } else {
                                0.0
                            }),
                        );
                    }
                    Family::Ssd => {
                        let z = self.rng.gaussian_vec_f32(b * l * self.vocab);
                        data.insert(
                            "z".into(),
                            Tensor::f32(&[b, l, self.vocab], z),
                        );
                    }
                    Family::Plaid => {
                        let eps =
                            self.rng.gaussian_vec_f32(b * l * self.d_model);
                        data.insert(
                            "eps".into(),
                            Tensor::f32(&[b, l, self.d_model], eps),
                        );
                    }
                }
            }
        }

        let inputs = self.store.assemble(&self.exe.spec, data)?;
        let out = self.exe.run(&inputs).context("train step")?;

        // absorb params + optimizer state
        let spec = self.exe.spec.clone();
        self.store.update_from_outputs(&spec, &out)?;
        for (i, oname) in spec.outputs.iter().enumerate() {
            if let Some(n) = oname.strip_prefix("m.") {
                self.opt.m.insert(n.to_string(), out[i].clone());
            } else if let Some(n) = oname.strip_prefix("v.") {
                self.opt.v.insert(n.to_string(), out[i].clone());
            }
        }
        self.opt.count =
            out[spec.output_index("count")?].item_f32()?;
        let loss = out[spec.output_index("loss")?].item_f32()?;
        self.step += 1;
        self.losses.push(loss);
        if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
            log_info!(
                "train[{}] step {} loss {:.4} lr {:.2e}",
                self.cfg.target.family_name(),
                self.step,
                loss,
                self.cfg.lr_at(self.step)
            );
        }
        Ok(loss)
    }

    /// Run `n` steps; returns the loss trace for those steps.
    pub fn run(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.train_step()?);
        }
        Ok(out)
    }

    /// Save a checkpoint (parameters only, PBIN).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        self.store.save(path)
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }
}
