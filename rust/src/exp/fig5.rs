//! Fig 5 — AR-NLL vs exit step per criterion per model (Prefix-32), and
//! Fig 6 — unique-token fraction vs exit step (diversity is unharmed).
//!
//! One recorded run per family supplies complete stats traces + per-step
//! token snapshots, so the fixed-exit grid and the adaptive-threshold
//! sweeps are evaluated post-hoc on identical generations.

use std::fmt::Write as _;

use anyhow::Result;

use super::common::{record_run, RunOpts, RunRecord};
use super::fig4::default_thresholds;
use super::Ctx;
use crate::eval::ngram;
use crate::halting::{parse_policy, BoxedPolicy, Entropy, Kl, Patience};
use crate::sampler::Family;
use crate::util::table::{f, Table};

const PREFIX: usize = 32;

struct Sweep {
    label: String,
    mean_exit: f64,
    value: f64,
}

fn fixed_grid(n_steps: usize) -> Vec<usize> {
    let mut g: Vec<usize> =
        (1..=10).map(|i| i * n_steps / 10).collect();
    g.dedup();
    g
}

/// The adaptive policy grid: threshold sweeps for each primitive plus
/// composed policies the open API enables (disjunction of the paper's
/// best signals, and a guarded entropy exit).
fn adaptive_grid(n_steps: usize) -> Vec<(String, BoxedPolicy)> {
    let (ent0, pat0, kl0) = default_thresholds(n_steps);
    let mut out: Vec<(String, BoxedPolicy)> = Vec::new();
    for mult in [0.25f32, 1.0, 4.0, 16.0] {
        out.push((
            format!("entropy:{:.3}", ent0 * mult),
            Box::new(Entropy::new(ent0 * mult)),
        ));
        out.push((
            format!("kl:{:.1e}", kl0 * mult),
            Box::new(Kl::new(kl0 * mult, n_steps / 4)),
        ));
    }
    for pat in [pat0 / 2, pat0, pat0 * 2, pat0 * 4] {
        out.push((
            format!("patience:{}", pat.max(1)),
            Box::new(Patience::new(pat.max(1), 0.0)),
        ));
    }
    for spec in [
        format!("any(entropy:{ent0},kl:{kl0}:{})", n_steps / 4),
        format!("all(entropy:{ent0},patience:{}:0)", pat0.max(1)),
        format!("min({},entropy:{})", n_steps / 4, ent0 * 4.0),
        format!("ema(0.3,entropy:{ent0})"),
    ] {
        let policy = parse_policy(&spec).expect("grid spec parses");
        out.push((spec, policy));
    }
    out
}

fn eval_exit<M>(rec: &RunRecord, exits: &[usize], metric: M) -> (f64, f64)
where
    M: Fn(&[Vec<i32>]) -> f64,
{
    let mean_exit =
        exits.iter().sum::<usize>() as f64 / exits.len() as f64;
    let samples: Vec<Vec<i32>> = exits
        .iter()
        .enumerate()
        .map(|(i, &e)| rec.tokens_at(i, e).to_vec())
        .collect();
    (mean_exit, metric(&samples))
}

fn sweep_family<M>(
    rec: &RunRecord,
    n_steps: usize,
    metric: M,
) -> Vec<Sweep>
where
    M: Fn(&[Vec<i32>]) -> f64 + Copy,
{
    let mut rows = Vec::new();
    for step in fixed_grid(n_steps) {
        let exits = vec![step; rec.traces.len()];
        let (me, v) = eval_exit(rec, &exits, metric);
        rows.push(Sweep {
            label: format!("fixed:{step}"),
            mean_exit: me,
            value: v,
        });
    }
    for (label, policy) in adaptive_grid(n_steps) {
        let exits: Vec<usize> = (0..rec.traces.len())
            .map(|i| rec.exit_step(i, policy.as_ref()))
            .collect();
        let (me, v) = eval_exit(rec, &exits, metric);
        rows.push(Sweep {
            label,
            mean_exit: me,
            value: v,
        });
    }
    rows
}

fn record_families(
    ctx: &Ctx,
) -> Result<Vec<(Family, RunRecord)>> {
    let n_steps = ctx.n_steps();
    let mut out = Vec::new();
    for fam in Family::all() {
        let store = ctx.store(fam.name())?;
        let mut opts = RunOpts::new(fam, ctx.n_samples(), n_steps);
        opts.prefix_len = PREFIX;
        opts.seed = 5;
        out.push((fam, record_run(ctx, store, opts)?));
    }
    Ok(out)
}

pub fn run_fig5(ctx: &Ctx) -> Result<String> {
    let scorer = ctx.scorer()?;
    let n_steps = ctx.n_steps();
    let recs = record_families(ctx)?;
    let mut out = format!(
        "Fig 5 — AR-NLL vs exit step per criterion (Prefix-32, \
         N_max={n_steps})\n\n"
    );
    for (fam, rec) in &recs {
        let metric = |samples: &[Vec<i32>]| -> f64 {
            scorer
                .mean_score(samples, PREFIX)
                .map(|v| v as f64)
                .unwrap_or(f64::NAN)
        };
        let rows = sweep_family(rec, n_steps, &metric);
        let full = rows
            .iter()
            .find(|r| r.label == format!("fixed:{n_steps}"))
            .map(|r| r.value)
            .unwrap_or(f64::NAN);
        let mut table =
            Table::new(&["criterion", "mean exit", "exit %", "AR-NLL", "ΔNLL vs full"]);
        for r in &rows {
            table.row(vec![
                r.label.clone(),
                f(r.mean_exit, 1),
                f(100.0 * r.mean_exit / n_steps as f64, 1),
                f(r.value, 3),
                f(r.value - full, 3),
            ]);
        }
        let _ = writeln!(out, "({})\n{}", fam.name(), table.render());
    }
    out.push_str(
        "paper-shape check: ddlm's adaptive criteria reach full-quality \
         NLL at the smallest exit %, ssd later; plaid needs ~the full \
         schedule (fixed criterion only).\n",
    );
    Ok(out)
}

pub fn run_fig6(ctx: &Ctx) -> Result<String> {
    let n_steps = ctx.n_steps();
    let recs = record_families(ctx)?;
    let mut out = format!(
        "Fig 6 — unique-token fraction vs exit criterion (Prefix-32, \
         N_max={n_steps})\n\n"
    );
    for (fam, rec) in &recs {
        let metric = |samples: &[Vec<i32>]| -> f64 {
            samples
                .iter()
                .map(|s| ngram::unique_fraction(&s[PREFIX..]))
                .sum::<f64>()
                / samples.len() as f64
        };
        let rows = sweep_family(rec, n_steps, &metric);
        let mut table =
            Table::new(&["criterion", "mean exit", "unique-token fraction"]);
        for r in &rows {
            table.row(vec![
                r.label.clone(),
                f(r.mean_exit, 1),
                f(r.value, 3),
            ]);
        }
        let _ = writeln!(out, "({})\n{}", fam.name(), table.render());
    }
    out.push_str(
        "paper-shape check: no criterion materially reduces the \
         unique-token fraction.\n",
    );
    Ok(out)
}
