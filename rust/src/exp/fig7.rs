//! Fig 7 — convergence of generations: (a) GPT-Score-lite and (b) WER of
//! the sample at step s against the final-step sample, per family.
//!
//! Paper finding: DDLM's samples stabilise (score ~10, WER ~0) around 60%
//! of the schedule, SSD ~85%, Plaid keeps evolving until the end — but
//! Plaid's late WER is small, so a fixed early exit still works.

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::eval::{judge, wer};
use crate::sampler::Family;
use crate::util::table::{f, sparkline, Table};

pub fn run(ctx: &Ctx) -> Result<String> {
    let n_steps = ctx.n_steps();
    let mut out = format!(
        "Fig 7 — side-by-side convergence vs the final sample \
         (N_max={n_steps})\n\n"
    );
    let mut score_table = Table::new(&[
        "model", "GPT-Score-lite curve", "@25%", "@50%", "@75%", "stabilises at",
    ]);
    let mut wer_table = Table::new(&[
        "model", "WER curve", "@25%", "@50%", "@75%", "@95%",
    ]);
    for fam in Family::all() {
        let store = ctx.store(fam.name())?;
        let mut opts =
            RunOpts::new(fam, ctx.n_samples().min(8), n_steps);
        opts.seed = 7;
        let rec = record_run(ctx, store, opts)?;
        let n = rec.traces.len();
        let mut score_curve = vec![0.0f64; n_steps];
        let mut wer_curve = vec![0.0f64; n_steps];
        for sample in 0..n {
            let final_tokens = rec.final_tokens(sample).to_vec();
            for step in 0..n_steps {
                let toks = &rec.snaps[sample][step];
                score_curve[step] +=
                    judge::gpt_score_lite(toks, &final_tokens) / n as f64;
                wer_curve[step] +=
                    wer::wer(toks, &final_tokens) / n as f64;
            }
        }
        let q = |c: &[f64], frac: f64| c[((c.len() - 1) as f64 * frac) as usize];
        // stabilisation: first step with score >= 9.9 that never drops
        let stab = (0..n_steps)
            .find(|&i| score_curve[i..].iter().all(|&v| v >= 9.9))
            .map(|i| format!("{}/{}", i + 1, n_steps))
            .unwrap_or_else(|| "never".into());
        score_table.row(vec![
            fam.name().to_string(),
            sparkline(&score_curve, 22),
            f(q(&score_curve, 0.25), 2),
            f(q(&score_curve, 0.5), 2),
            f(q(&score_curve, 0.75), 2),
            stab,
        ]);
        wer_table.row(vec![
            fam.name().to_string(),
            sparkline(&wer_curve, 22),
            f(q(&wer_curve, 0.25), 3),
            f(q(&wer_curve, 0.5), 3),
            f(q(&wer_curve, 0.75), 3),
            f(q(&wer_curve, 0.95), 3),
        ]);
    }
    out.push_str("(a) GPT-Score-lite vs final sample\n");
    out.push_str(&score_table.render());
    out.push_str("\n(b) WER vs final sample\n");
    out.push_str(&wer_table.render());
    out.push_str(
        "\npaper-shape check: ddlm stabilises earliest, ssd later, plaid \
         last — but plaid's WER near the end is already small.\n",
    );
    Ok(out)
}
