//! Experiment harness: one module per paper figure/table (DESIGN.md §5).
//!
//! Every experiment prints the same rows/series the paper reports, through
//! `util::table`, and returns the rendered text so the bench targets and
//! the `repro exp <id>` subcommand share one code path.
//!
//! Scaling note (EXPERIMENTS.md): the paper evaluates 147M-1.3B models at
//! 1000 generation steps over 1k-5k C4 samples; this repo evaluates ~0.6M
//! models at `Ctx::n_steps()` steps over `Ctx::n_samples()` synthetic
//! sequences.  Exit points are therefore compared as *fractions of N_max*.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod headline;
pub mod tab3;
pub mod tab4;

use std::rc::Rc;

use anyhow::{bail, Context as _, Result};

use crate::corpus::dataset::Dataset;
use crate::eval::arnll::ArScorer;
use crate::log_warn;
use crate::models::store::ParamStore;
use crate::runtime::Runtime;

/// Shared experiment context: runtime + trained checkpoints + sizing.
pub struct Ctx {
    pub rt: Runtime,
    pub artifact_dir: String,
    /// directory holding trained checkpoints (`<family>.pbin`,
    /// `ddlm_ck<step>.pbin`), produced by `repro prepare`
    pub runs_dir: String,
    /// reduced sizes for bench/smoke runs
    pub quick: bool,
}

impl Ctx {
    pub fn new(artifact_dir: &str, runs_dir: &str, quick: bool) -> Result<Ctx> {
        Ok(Ctx {
            rt: Runtime::new(artifact_dir)?,
            artifact_dir: artifact_dir.to_string(),
            runs_dir: runs_dir.to_string(),
            quick,
        })
    }

    /// Trained parameters for a family; falls back to init params (with a
    /// warning — figures are only meaningful after `repro prepare`).
    pub fn store(&self, family: &str) -> Result<Rc<ParamStore>> {
        let path = format!("{}/{}.pbin", self.runs_dir, family);
        if std::path::Path::new(&path).exists() {
            Ok(Rc::new(ParamStore::load(&path, family)?))
        } else {
            log_warn!(
                "no trained checkpoint {path}; using init params \
                 (run `repro prepare` first)"
            );
            Ok(Rc::new(ParamStore::load_init(&self.artifact_dir, family)?))
        }
    }

    /// DDLM pre-training checkpoints (train_step, params) for Fig 1/2.
    pub fn ddlm_checkpoints(&self) -> Result<Vec<(usize, Rc<ParamStore>)>> {
        let mut out = Vec::new();
        let dir = std::fs::read_dir(&self.runs_dir)
            .with_context(|| format!("read {} — run `repro prepare`", self.runs_dir))?;
        for e in dir.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(step) = name
                .strip_prefix("ddlm_ck")
                .and_then(|s| s.strip_suffix(".pbin"))
                .and_then(|s| s.parse::<usize>().ok())
            {
                out.push((
                    step,
                    Rc::new(ParamStore::load(e.path(), "ddlm")?),
                ));
            }
        }
        if out.is_empty() {
            bail!("no ddlm_ck*.pbin checkpoints in {}", self.runs_dir);
        }
        out.sort_by_key(|(s, _)| *s);
        Ok(out)
    }

    pub fn scorer(&self) -> Result<ArScorer> {
        ArScorer::new(&self.rt, self.store("ar")?)
    }

    pub fn dataset(&self) -> Dataset {
        let m = &self.rt.manifest.model;
        Dataset::new(m.vocab, m.seq_len)
    }

    /// Samples per condition.
    pub fn n_samples(&self) -> usize {
        if self.quick {
            8
        } else {
            24
        }
    }

    /// Generation steps (N_max).  The paper uses 1000; exit points are
    /// compared as fractions of N_max.
    pub fn n_steps(&self) -> usize {
        if self.quick {
            48
        } else {
            200
        }
    }
}

/// Experiment registry: id -> runner.
pub fn run(ctx: &Ctx, id: &str) -> Result<String> {
    match id {
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run_fig3(ctx),
        "tab1" => fig3::run_tab1(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run_fig5(ctx),
        "fig6" => fig5::run_fig6(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "tab3" => tab3::run(ctx),
        "tab4" => tab4::run(ctx),
        "headline" => headline::run(ctx),
        other => bail!(
            "unknown experiment {other}; known: fig1 fig2 fig3 tab1 fig4 \
             fig5 fig6 fig7 fig8 tab3 tab4 headline"
        ),
    }
}

/// Entry point shared by the `cargo bench` targets: run one experiment in
/// quick mode (and full mode with `--full`), timing it — each bench target
/// regenerates its paper table/figure.
pub fn bench_main(id: &str) {
    crate::util::log::init();
    let args = crate::util::cli::Args::from_env();
    // `cargo bench` passes --bench; ignore unknown harness flags
    let quick = !args.flag("full");
    let ctx = Ctx::new(
        args.get_or("artifacts", "artifacts"),
        args.get_or("runs", "runs"),
        quick,
    )
    .expect("artifacts missing — run `make artifacts`");
    let t0 = std::time::Instant::now();
    match run(&ctx, id) {
        Ok(text) => {
            println!("{text}");
            println!(
                "bench {id}: {:.2}s ({})",
                t0.elapsed().as_secs_f64(),
                if quick { "quick" } else { "full" }
            );
        }
        Err(e) => {
            eprintln!("bench {id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}

pub fn all_ids() -> &'static [&'static str] {
    &[
        "fig1", "fig2", "fig3", "tab1", "fig4", "fig5", "fig6", "fig7",
        "fig8", "tab3", "tab4", "headline",
    ]
}
