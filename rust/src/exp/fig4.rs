//! Fig 4 — the three adaptive-criterion signals per family:
//! (a) entropy, (b) consecutive unchanged-step count, (c) KL divergence,
//! with the default thresholds marked.
//!
//! Paper finding: DDLM crosses its thresholds early, SSD late (~85% of
//! the schedule), Plaid's signals stay flat (entropy decays only linearly)
//! — Plaid only supports the fixed criterion.

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::sampler::Family;
use crate::util::table::{sparkline, Table};

/// Default thresholds (calibrated on the trained models; see
/// EXPERIMENTS.md §calibration).  Per-step KL shrinks with finer
/// schedules (consecutive distributions get closer as dt shrinks), so the
/// KL threshold scales with 1/N_max; entropy is schedule-free.
pub fn default_thresholds(n_steps: usize) -> (f32, usize, f32) {
    // (entropy threshold, patience steps, kl threshold)
    (0.25, (n_steps / 16).max(3), 0.12 / n_steps as f32)
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let n_steps = ctx.n_steps();
    let (ent_thr, patience, kl_thr) = default_thresholds(n_steps);
    let mut out = format!(
        "Fig 4 — halting-criterion signals vs step (N_max={n_steps}; \
         thresholds: entropy<={ent_thr}, patience={patience} unchanged \
         steps, KL<={kl_thr})\n\n",
    );
    let mut table = Table::new(&[
        "model",
        "entropy curve",
        "unchanged-run curve",
        "KL curve",
        "H cross",
        "patience cross",
        "KL cross",
    ]);
    for fam in Family::all() {
        let store = ctx.store(fam.name())?;
        let mut opts =
            RunOpts::new(fam, ctx.n_samples().min(8), n_steps);
        opts.seed = 4;
        let rec = record_run(ctx, store, opts)?;
        let ent = rec.mean_curve(|s| s.entropy);
        let kl = rec.mean_curve(|s| s.kl);
        // mean consecutive-unchanged run length per step
        let n = rec.traces.len();
        let mut run_curve = vec![0.0f64; n_steps];
        for t in &rec.traces {
            let mut run = 0usize;
            for (i, s) in t.iter().enumerate() {
                if i > 0 && s.switches < 0.5 {
                    run += 1;
                } else {
                    run = 0;
                }
                run_curve[i] += run as f64 / n as f64;
            }
        }
        let cross = |c: &[f64], thr: f64, above: bool| -> String {
            c.iter()
                .position(|&v| if above { v >= thr } else { v <= thr })
                .map(|i| format!("{}/{}", i + 1, n_steps))
                .unwrap_or_else(|| "never".into())
        };
        table.row(vec![
            fam.name().to_string(),
            sparkline(&ent, 20),
            sparkline(&run_curve, 20),
            sparkline(&kl, 20),
            cross(&ent, ent_thr as f64, false),
            cross(&run_curve, patience as f64, true),
            // skip the first few steps for KL (min_steps guard)
            {
                let ms = n_steps / 4;
                kl.iter()
                    .enumerate()
                    .position(|(i, &v)| i + 1 >= ms && i > 0 && v <= kl_thr as f64)
                    .map(|i| format!("{}/{}", i + 1, n_steps))
                    .unwrap_or_else(|| "never".into())
            },
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\npaper-shape check: ddlm crosses earliest; ssd crosses late; \
         plaid's adaptive signals cross at the very end or never.\n",
    );
    Ok(out)
}
