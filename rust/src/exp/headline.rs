//! Headline claim (§5.4): adaptive halting cuts generation time by
//! 10-40% with no quality drop — measured end-to-end through the serving
//! coordinator (continuous batching with early-exit slot recycling).
//!
//! For each family we serve the same request stream twice: once with the
//! family's best adaptive criterion (fixed-step for Plaid, per the paper)
//! and once without halting, and compare wall-clock, throughput and
//! AR-NLL of the outputs.

use std::fmt::Write as _;
use std::time::Instant;

use anyhow::Result;

use super::fig4::default_thresholds;
use super::Ctx;
use crate::coordinator::{start, EngineConfig, GenRequest};
use crate::halting::{BoxedPolicy, Fixed, HaltPolicy, Kl, NoHalt};
use crate::sampler::Family;
use crate::util::json::Json;
use crate::util::table::{f, Table};

const PREFIX: usize = 32;

struct ServeResult {
    wall_s: f64,
    mean_latency_ms: f64,
    mean_steps: f64,
    nll: f64,
    device_calls: f64,
}

fn serve_stream(
    ctx: &Ctx,
    family: Family,
    policy: &BoxedPolicy,
    n_requests: usize,
    n_steps: usize,
) -> Result<ServeResult> {
    let mut cfg = EngineConfig::new(&ctx.artifact_dir, family);
    cfg.worker_specs = vec![(family.into(), 8)];
    cfg.discover_checkpoints(&ctx.runs_dir);
    let (engine, join) = start(cfg);

    let ds = ctx.dataset();
    let prompts = ds.val_prompts(777, n_requests);
    let t0 = Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut req = GenRequest::new(i as u64, n_steps);
            req.prefix = p[..PREFIX].to_vec();
            req.policy = policy.clone();
            req.seed = 5000 + i as u64;
            engine.submit(req)
        })
        .collect();
    let mut outputs = Vec::new();
    let mut lat = 0.0;
    let mut steps = 0usize;
    for rx in rxs {
        let r = rx.recv()??;
        lat += r.latency_ms;
        steps += r.steps_executed;
        outputs.push(r.tokens);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = engine.metrics()?;
    let device_calls = metrics
        .get("device_calls")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    engine.shutdown();
    join.join().unwrap()?;

    let nll = ctx.scorer()?.mean_score(&outputs, PREFIX)? as f64;
    Ok(ServeResult {
        wall_s,
        mean_latency_ms: lat / n_requests as f64,
        mean_steps: steps as f64 / n_requests as f64,
        nll,
        device_calls,
    })
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let n_steps = ctx.n_steps();
    let n_requests = if ctx.quick { 16 } else { 32 };
    let (_, _, kl0) = default_thresholds(n_steps);
    let mut out = format!(
        "Headline — serving-time reduction from early halting \
         ({n_requests} Prefix-32 requests, N_max={n_steps}, batch=8, \
         continuous batching)\n\n"
    );
    let mut table = Table::new(&[
        "model",
        "criterion",
        "wall s",
        "Δwall %",
        "mean steps",
        "mean latency ms",
        "device calls",
        "AR-NLL",
        "ΔNLL",
    ]);
    let no_halt: BoxedPolicy = Box::new(NoHalt);
    for fam in Family::all() {
        // the paper's per-family best: KL for ddlm/ssd, fixed for plaid
        // lint:allow(family-seal): experiment config table, not serving dispatch
        let policy: BoxedPolicy = match fam {
            Family::Ddlm | Family::Ssd => {
                Box::new(Kl::new(kl0, n_steps / 4))
            }
            Family::Plaid => Box::new(Fixed::new(n_steps * 9 / 10)),
        };
        let base = serve_stream(ctx, fam, &no_halt, n_requests, n_steps)?;
        let halt = serve_stream(ctx, fam, &policy, n_requests, n_steps)?;
        let dw = 100.0 * (base.wall_s - halt.wall_s) / base.wall_s;
        table.row(vec![
            fam.name().into(),
            "none".into(),
            f(base.wall_s, 2),
            "-".into(),
            f(base.mean_steps, 1),
            f(base.mean_latency_ms, 1),
            f(base.device_calls, 0),
            f(base.nll, 3),
            "-".into(),
        ]);
        table.row(vec![
            fam.name().into(),
            policy.name().into(),
            f(halt.wall_s, 2),
            f(dw, 1),
            f(halt.mean_steps, 1),
            f(halt.mean_latency_ms, 1),
            f(halt.device_calls, 0),
            f(halt.nll, 3),
            f(halt.nll - base.nll, 3),
        ]);
    }
    let _ = writeln!(out, "{}", table.render());
    out.push_str(
        "paper claim: 40% (DDLM), 10-15% (SSD), 10% (Plaid) time \
         reduction at ΔNLL ≈ 0.\n",
    );
    Ok(out)
}
