//! Shared generation recorder for the experiment harness.
//!
//! One recorded run = full per-step statistics traces + per-step token
//! snapshots for every sample.  Because the traces are complete, *any*
//! halting criterion/threshold can be evaluated post-hoc without
//! re-generating — this is how the Fig 5/6 threshold sweeps stay cheap.

use std::rc::Rc;

use anyhow::Result;

use super::Ctx;
use crate::halting::{HaltPolicy, StepStats};
use crate::models::store::ParamStore;
use crate::sampler::{Family, Session, SlotRequest};

#[derive(Clone, Debug)]
pub struct RunOpts {
    pub family: Family,
    pub n_samples: usize,
    pub n_steps: usize,
    pub prefix_len: usize,
    pub noise_scale: f32,
    /// noise/init seed (vary for multi-seed sampling from one prompt set)
    pub seed: u64,
    /// validation-prompt seed (keep fixed to share prompts across runs)
    pub data_seed: u64,
    /// also record x / x0_hat trajectories (Fig 2 only; memory-heavy)
    pub record_vectors: bool,
    /// seq_len override (Fig 8 long-sequence runs); 0 = manifest default
    pub seq_len: usize,
}

impl RunOpts {
    pub fn new(family: Family, n_samples: usize, n_steps: usize) -> RunOpts {
        RunOpts {
            family,
            n_samples,
            n_steps,
            prefix_len: 0,
            noise_scale: 1.0,
            seed: 20240710,
            data_seed: 777,
            record_vectors: false,
            seq_len: 0,
        }
    }
}

/// Full record of one generation run.
pub struct RunRecord {
    pub opts: RunOpts,
    /// per-sample per-step statistics
    pub traces: Vec<Vec<StepStats>>,
    /// per-sample per-step argmax tokens (snapshot after each step)
    pub snaps: Vec<Vec<Vec<i32>>>,
    /// reference sequences the prompts came from (full length)
    pub references: Vec<Vec<i32>>,
    /// optional x trajectories [sample][step][row] (Fig 2)
    pub xs: Vec<Vec<Vec<f32>>>,
    /// optional x0_hat trajectories (Fig 2)
    pub x0s: Vec<Vec<Vec<f32>>>,
}

impl RunRecord {
    pub fn final_tokens(&self, sample: usize) -> &[i32] {
        self.snaps[sample].last().unwrap()
    }

    /// Tokens at 1-based exit step `s` (s=0 -> first step's snapshot).
    pub fn tokens_at(&self, sample: usize, exit_step: usize) -> &[i32] {
        let idx = exit_step.saturating_sub(1).min(self.snaps[sample].len() - 1);
        &self.snaps[sample][idx]
    }

    /// First 1-based step at which `policy` fires (or n_steps if never;
    /// 0 when the policy resolves in preflight, e.g. `fixed:0`).  The
    /// policy is cloned + reset internally, so any post-hoc sweep can
    /// reuse one policy value across samples.
    pub fn exit_step(&self, sample: usize, policy: &dyn HaltPolicy) -> usize {
        let mut p = policy.clone_box();
        p.reset();
        if p.preflight().halted() {
            return 0;
        }
        for (i, stats) in self.traces[sample].iter().enumerate() {
            if p.observe(i, stats).halted() {
                return i + 1;
            }
        }
        self.traces[sample].len()
    }

    /// Mean of a stats field across samples at each step.
    pub fn mean_curve(&self, f: impl Fn(&StepStats) -> f32) -> Vec<f64> {
        let n_steps = self.traces[0].len();
        let mut out = vec![0.0; n_steps];
        for t in &self.traces {
            for (i, s) in t.iter().enumerate() {
                out[i] += f(s) as f64;
            }
        }
        for o in &mut out {
            *o /= self.traces.len() as f64;
        }
        out
    }
}

/// Run batched generation, recording everything.
pub fn record_run(
    ctx: &Ctx,
    store: Rc<ParamStore>,
    opts: RunOpts,
) -> Result<RunRecord> {
    let m = ctx.rt.manifest.model.clone();
    let seq_len = if opts.seq_len == 0 { m.seq_len } else { opts.seq_len };
    let batch = ctx.rt.manifest.resolve_step_batch(
        opts.family.name(),
        seq_len,
        8,
    )?;
    let mut session =
        Session::new(&ctx.rt, opts.family, store, batch, seq_len)?;
    // x / x0_hat trajectories cost ~L*D floats per slot per step to
    // download — only pay for them when the caller wants vectors
    // (recording pins the session to the host-roundtrip path)
    session.set_record_x0(opts.record_vectors)?;

    // deterministic validation prompts (prefix task uses their heads)
    let ds = crate::corpus::dataset::Dataset::new(m.vocab, seq_len);
    let references = ds.val_prompts(opts.data_seed, opts.n_samples);

    let mut traces = vec![Vec::new(); opts.n_samples];
    let mut snaps = vec![Vec::new(); opts.n_samples];
    let mut xs = vec![Vec::new(); opts.n_samples];
    let mut x0s = vec![Vec::new(); opts.n_samples];

    for group in (0..opts.n_samples).collect::<Vec<_>>().chunks(batch) {
        for (slot, &sample) in group.iter().enumerate() {
            let prefix = &references[sample][..opts.prefix_len];
            session.reset_slot(
                slot,
                &SlotRequest::new(
                    opts.seed ^ (sample as u64).wrapping_mul(0x9E37_79B9),
                    opts.n_steps,
                    m.t_max,
                    m.t_min,
                )
                .noise(opts.noise_scale)
                .prefix(prefix),
            )?;
        }
        // idle out unused slots in the tail group
        for slot in group.len()..batch {
            session.release_slot(slot);
        }
        for _ in 0..opts.n_steps {
            let stats = session.step()?;
            for (slot, &sample) in group.iter().enumerate() {
                let st = stats[slot].expect("active slot");
                traces[sample].push(st);
                snaps[sample].push(session.slot_output(slot));
                if opts.record_vectors {
                    xs[sample].push(session.slot_x(slot).to_vec());
                    x0s[sample].push(session.slot_x0_hat(slot).to_vec());
                }
            }
        }
    }
    Ok(RunRecord {
        opts,
        traces,
        snaps,
        references,
        xs,
        x0s,
    })
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if na * nb <= 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halting::{parse_policy, Entropy, Fixed, Patience};

    fn fake_record(n_samples: usize, n_steps: usize) -> RunRecord {
        // synthetic record: entropy decays geometrically, kl decays,
        // switches hit zero halfway; tokens converge at 60%
        let mut traces = Vec::new();
        let mut snaps = Vec::new();
        for s in 0..n_samples {
            let mut t = Vec::new();
            let mut sn = Vec::new();
            for i in 0..n_steps {
                let frac = i as f32 / n_steps as f32;
                t.push(StepStats {
                    entropy: 4.0 * (1.0 - frac).powi(2),
                    kl: 0.1 * (-8.0 * frac).exp(),
                    switches: if frac < 0.5 { 10.0 } else { 0.0 },
                    norm_x0: 8.0,
                    norm_x: 8.0 + 20.0 * (1.0 - frac),
                });
                let settled = frac >= 0.6;
                sn.push(if settled {
                    vec![s as i32; 8]
                } else {
                    vec![i as i32; 8]
                });
            }
            traces.push(t);
            snaps.push(sn);
        }
        RunRecord {
            opts: RunOpts::new(Family::Ddlm, n_samples, n_steps),
            traces,
            snaps,
            references: vec![vec![0; 8]; n_samples],
            xs: Vec::new(),
            x0s: Vec::new(),
        }
    }

    #[test]
    fn exit_step_entropy_matches_threshold() {
        let rec = fake_record(2, 100);
        // entropy = 4 (1-f)^2 <= 1.0  =>  f >= 0.5
        let e = rec.exit_step(0, &Entropy::new(1.0));
        assert!((48..=53).contains(&e), "exit={e}");
    }

    #[test]
    fn exit_step_never_fires_returns_n_steps() {
        let rec = fake_record(1, 50);
        let e = rec.exit_step(0, &Entropy::new(-1.0));
        assert_eq!(e, 50);
    }

    #[test]
    fn exit_step_patience_after_switch_freeze() {
        let rec = fake_record(1, 100);
        // switches are 0 from step 50 on; patience 10 -> fires ~step 60
        let e = rec.exit_step(0, &Patience::new(10, 0.0));
        assert!((58..=62).contains(&e), "exit={e}");
    }

    #[test]
    fn exit_step_preflight_resolves_to_zero() {
        let rec = fake_record(1, 20);
        assert_eq!(rec.exit_step(0, &Fixed::new(0)), 0);
        assert_eq!(rec.exit_step(0, &Fixed::new(5)), 5);
    }

    #[test]
    fn exit_step_evaluates_combinator_policies_post_hoc() {
        let rec = fake_record(1, 100);
        // any(): whichever fires first wins — here the fixed leg
        let any = parse_policy("any(entropy:1.0,fixed:30)").unwrap();
        assert_eq!(rec.exit_step(0, any.as_ref()), 30);
        // min() guard delays the entropy exit (~51) to step 80
        let guarded = parse_policy("min(80,entropy:1.0)").unwrap();
        assert_eq!(rec.exit_step(0, guarded.as_ref()), 80);
        // all(): waits for the later of the two signals
        let both = parse_policy("all(entropy:1.0,patience:10:0)").unwrap();
        let e = rec.exit_step(0, both.as_ref());
        assert!((58..=62).contains(&e), "exit={e}");
        // the same boxed policy value is reusable across samples
        assert_eq!(rec.exit_step(0, any.as_ref()), 30);
    }

    #[test]
    fn tokens_at_clamps_and_final_matches() {
        let rec = fake_record(1, 40);
        assert_eq!(rec.tokens_at(0, 0), rec.snaps[0][0].as_slice());
        assert_eq!(rec.tokens_at(0, 10_000), rec.final_tokens(0));
    }

    #[test]
    fn mean_curve_averages_samples() {
        let rec = fake_record(4, 20);
        let c = rec.mean_curve(|s| s.norm_x0);
        assert_eq!(c.len(), 20);
        assert!(c.iter().all(|&v| (v - 8.0).abs() < 1e-6));
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-2.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn thin_keeps_endpoints() {
        let c: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let t = thin(&c, 10);
        assert_eq!(t.first().unwrap().0, 0);
        assert_eq!(t.last().unwrap().0, 99);
        assert!(t.len() <= 12);
    }
}

/// Downsample a curve to ~`k` points for table display (keeps endpoints).
pub fn thin(curve: &[f64], k: usize) -> Vec<(usize, f64)> {
    if curve.is_empty() {
        return Vec::new();
    }
    let stride = (curve.len() as f64 / k as f64).max(1.0);
    let mut out = Vec::new();
    let mut i = 0.0;
    while (i as usize) < curve.len() {
        out.push((i as usize, curve[i as usize]));
        i += stride;
    }
    if out.last().map(|(i, _)| *i) != Some(curve.len() - 1) {
        out.push((curve.len() - 1, curve[curve.len() - 1]));
    }
    out
}
