//! Fig 2 — DDLM generation-state geometry vs step, per checkpoint:
//! (a) ||x0_hat||_2, (b) ||X||_2, (c) cos(score, final score),
//! (d) cos(X, final X).
//!
//! Paper finding: beyond mid-generation the score direction freezes and X
//! travels to the embedding sphere through its interior (||X|| dips then
//! recovers towards sqrt(D)).

use anyhow::Result;

use super::common::{cosine, record_run, RunOpts};
use super::Ctx;
use crate::sampler::Family;
use crate::util::table::{f, sparkline, Table};

pub fn run(ctx: &Ctx) -> Result<String> {
    let checkpoints = ctx.ddlm_checkpoints()?;
    let n_steps = ctx.n_steps().min(120); // vector recording is memory-heavy
    let n_samples = 4usize;
    let mut out = String::from(
        "Fig 2 — DDLM state geometry vs generation step (per checkpoint)\n\n",
    );
    let mut table = Table::new(&[
        "train_step",
        "||x0_hat|| curve",
        "||X|| curve",
        "cos(S, S_final) curve",
        "cos(X, X_final) curve",
        "cos(S,Sf)@50%",
        "||X|| min",
        "||X|| final",
    ]);

    for (train_step, store) in checkpoints {
        let mut opts = RunOpts::new(Family::Ddlm, n_samples, n_steps);
        opts.record_vectors = true;
        opts.seed = 2;
        let rec = record_run(ctx, store, opts)?;
        let norm_x0 = rec.mean_curve(|s| s.norm_x0);
        let norm_x = rec.mean_curve(|s| s.norm_x);

        // score at step i: S_i = (x0_hat_i - x_i) / t_i^2; cos vs final.
        // the 1/t^2 scale cancels in the cosine, so compare directions of
        // (x0_hat - x) directly.
        let mut cos_s = vec![0.0f64; n_steps];
        let mut cos_x = vec![0.0f64; n_steps];
        for sample in 0..n_samples {
            let xs = &rec.xs[sample];
            let x0s = &rec.x0s[sample];
            let last = n_steps - 1;
            // DDLM x rows are L*D like x0_hat rows
            let s_final: Vec<f32> = x0s[last]
                .iter()
                .zip(&xs[last])
                .map(|(a, b)| a - b)
                .collect();
            let x_final = &xs[last];
            for i in 0..n_steps {
                let s_i: Vec<f32> = x0s[i]
                    .iter()
                    .zip(&xs[i])
                    .map(|(a, b)| a - b)
                    .collect();
                cos_s[i] += cosine(&s_i, &s_final) / n_samples as f64;
                cos_x[i] += cosine(&xs[i], x_final) / n_samples as f64;
            }
        }
        let min_x = norm_x.iter().cloned().fold(f64::INFINITY, f64::min);
        table.row(vec![
            train_step.to_string(),
            sparkline(&norm_x0, 18),
            sparkline(&norm_x, 18),
            sparkline(&cos_s, 18),
            sparkline(&cos_x, 18),
            f(cos_s[n_steps / 2], 3),
            f(min_x, 2),
            f(*norm_x.last().unwrap(), 2),
        ]);
    }
    out.push_str(&table.render());
    let d = ctx.rt.manifest.model.d_model as f64;
    out.push_str(&format!(
        "\nembedding-sphere radius sqrt(D) = {:.2}; paper-shape check: \
         ||x0_hat|| locks onto it early,\n||X|| dips (interior traversal) \
         then returns towards it; cos(S, S_final) saturates by \
         mid-generation.\n",
        d.sqrt()
    ));
    Ok(out)
}
