//! Fig 1 — token switches (a) and entropy of p(x|X(t),t) (b) vs
//! generation step, one curve per DDLM pre-training checkpoint.
//!
//! Paper finding: the trained model reaches zero switches / minimum
//! entropy well before the schedule ends (≈ step 100 of 200) — the
//! emergence of early-exit behaviour.

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::sampler::Family;
use crate::util::table::{f, sparkline, Table};

pub fn run(ctx: &Ctx) -> Result<String> {
    let checkpoints = ctx.ddlm_checkpoints()?;
    let n_steps = ctx.n_steps();
    let mut out = String::from(
        "Fig 1 — DDLM token switches & entropy vs generation step\n\
         (color in the paper = pre-training step; here one row per ckpt)\n\n",
    );

    let mut sw_table = Table::new(&["train_step", "switches curve", "sw@25%", "sw@50%", "sw@100%", "first-zero-switch step"]);
    let mut en_table = Table::new(&["train_step", "entropy curve", "H@25%", "H@50%", "H@100%", "min H"]);

    for (train_step, store) in checkpoints {
        let mut opts =
            RunOpts::new(Family::Ddlm, ctx.n_samples().min(16), n_steps);
        opts.seed = 1;
        let rec = record_run(ctx, store, opts)?;
        let sw = rec.mean_curve(|s| s.switches);
        let en = rec.mean_curve(|s| s.entropy);
        let q = |c: &[f64], frac: f64| c[((c.len() - 1) as f64 * frac) as usize];
        let first_zero = sw
            .iter()
            .position(|&s| s < 0.5)
            .map(|i| format!("{}", i + 1))
            .unwrap_or_else(|| "never".into());
        sw_table.row(vec![
            train_step.to_string(),
            sparkline(&sw, 24),
            f(q(&sw, 0.25), 2),
            f(q(&sw, 0.5), 2),
            f(q(&sw, 1.0), 2),
            first_zero,
        ]);
        let min_h = en.iter().cloned().fold(f64::INFINITY, f64::min);
        en_table.row(vec![
            train_step.to_string(),
            sparkline(&en, 24),
            f(q(&en, 0.25), 3),
            f(q(&en, 0.5), 3),
            f(q(&en, 1.0), 3),
            f(min_h, 3),
        ]);
    }
    out.push_str("(a) token switches per step (mean over samples)\n");
    out.push_str(&sw_table.render());
    out.push_str("\n(b) entropy of p(x|X(t),t)\n");
    out.push_str(&en_table.render());
    out.push_str(
        "\npaper-shape check: switches & entropy should fall towards zero \
         before the last step,\nand do so earlier for more-trained \
         checkpoints.\n",
    );
    Ok(out)
}
