//! Fig 3 + Table 1 — the initial-noise-scale knob.
//!
//! Fig 3: ||X||_2 trajectory for each initial scale of X (lower scale ->
//! the trajectory reaches its minimum sooner).
//! Table 1: AR-NLL / dist-1/2/3 / Self-BLEU vs noise scale — low scales
//! collapse diversity (sBLEU -> 1), scale ~1.0 is the operating point.

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::eval::ngram;
use crate::sampler::Family;
use crate::util::table::{f, sparkline, Table};

pub const NOISE_SCALES: &[f32] = &[0.0, 0.5, 0.8, 0.9, 1.0, 1.1, 1.2];

pub fn run_fig3(ctx: &Ctx) -> Result<String> {
    let store = ctx.store("ddlm")?;
    let n_steps = ctx.n_steps();
    let mut out = String::from(
        "Fig 3 — ||X||_2 during DDLM generation for different initial \
         noise scales\n\n",
    );
    let mut table = Table::new(&[
        "noise", "||X|| curve", "min step", "min ||X||", "final ||X||",
    ]);
    for &scale in NOISE_SCALES {
        let mut opts =
            RunOpts::new(Family::Ddlm, ctx.n_samples().min(8), n_steps);
        opts.noise_scale = scale;
        opts.seed = 3;
        let rec = record_run(ctx, store.clone(), opts)?;
        let curve = rec.mean_curve(|s| s.norm_x);
        let (min_i, min_v) = curve
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, v)| (i, *v))
            .unwrap();
        table.row(vec![
            format!("{scale:.1}"),
            sparkline(&curve, 24),
            (min_i + 1).to_string(),
            f(min_v, 2),
            f(*curve.last().unwrap(), 2),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\npaper-shape check: lower initial scale reaches the ||X|| \
         minimum earlier.\n",
    );
    Ok(out)
}

pub fn run_tab1(ctx: &Ctx) -> Result<String> {
    let store = ctx.store("ddlm")?;
    let scorer = ctx.scorer()?;
    let n_steps = ctx.n_steps();
    let prefix = 32usize;
    let n_prompts = ctx.n_samples().min(8);
    let seeds_per_prompt = 5usize; // paper: 5 continuations per prompt

    let mut out = String::from(
        "Table 1 — DDLM quality/diversity vs initial noise scale \
         (Prefix-32, 5 seeds per prompt)\n\n",
    );
    let mut table = Table::new(&[
        "Noise", "AR-NLL", "dist_1", "dist_2", "dist_3", "sBLEU",
    ]);
    for &scale in NOISE_SCALES {
        // groups[prompt][seed] = generated sequence
        let mut groups: Vec<Vec<Vec<i32>>> = vec![Vec::new(); n_prompts];
        for seed in 0..seeds_per_prompt {
            let mut opts = RunOpts::new(Family::Ddlm, n_prompts, n_steps);
            opts.noise_scale = scale;
            opts.prefix_len = prefix;
            opts.seed = 1000 + seed as u64; // same prompts, fresh noise
            let rec = record_run(ctx, store.clone(), opts)?;
            for p in 0..n_prompts {
                groups[p].push(rec.final_tokens(p).to_vec());
            }
        }
        // AR-NLL over everything (scoring only the generated suffix)
        let flat: Vec<Vec<i32>> =
            groups.iter().flatten().cloned().collect();
        let nll = scorer.mean_score(&flat, prefix)?;
        // diversity over the generated suffixes, per prompt group
        let (mut d1, mut d2, mut d3, mut sb) = (0.0, 0.0, 0.0, 0.0);
        for g in &groups {
            let suffixes: Vec<Vec<i32>> =
                g.iter().map(|s| s[prefix..].to_vec()).collect();
            d1 += ngram::dist_n(&suffixes, 1);
            d2 += ngram::dist_n(&suffixes, 2);
            d3 += ngram::dist_n(&suffixes, 3);
            sb += ngram::self_bleu(&suffixes);
        }
        let n = n_prompts as f64;
        table.row(vec![
            format!("{scale:.1}"),
            f(nll as f64, 2),
            f(d1 / n, 2),
            f(d2 / n, 2),
            f(d3 / n, 2),
            f(sb / n, 2),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\npaper-shape check: scale 0.0 degenerates (sBLEU=1, dist=0); \
         AR-NLL grows and diversity rises with scale; ~0.9-1.0 is the \
         knee.\n",
    );
    Ok(out)
}
