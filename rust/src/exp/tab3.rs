//! Table 3 — all models at varying step counts, Unconditional and
//! Prefix-32: AR-NLL, dist-1/2/3, MAUVE-lite, Zipf coefficient; plus the
//! Data row and the autoregressive baseline (the AR evaluator sampling
//! from itself stands in for GPT-2/GPT-Neo, DESIGN.md §8).

use std::fmt::Write as _;

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::eval::{argen::ArGenerator, mauve, ngram};
use crate::sampler::Family;
use crate::util::table::{f, Table};

const PREFIX: usize = 32;

fn step_grid(n_max: usize) -> Vec<usize> {
    // paper uses {50, 200, 1000}; scale to our N_max
    vec![n_max / 4, n_max / 2, n_max]
}

struct Row {
    model: String,
    steps: String,
    sampler: String,
    nll: f64,
    d1: f64,
    d2: f64,
    d3: f64,
    mauve: f64,
    zipf: f64,
}

fn metrics_row(
    ctx: &Ctx,
    label: (&str, &str, &str),
    samples: &[Vec<i32>],
    references: &[Vec<i32>],
    prefix: usize,
) -> Result<Row> {
    let scorer = ctx.scorer()?;
    let nll = scorer.mean_score(samples, prefix)? as f64;
    let suffixes: Vec<Vec<i32>> =
        samples.iter().map(|s| s[prefix..].to_vec()).collect();
    let ref_suffixes: Vec<Vec<i32>> =
        references.iter().map(|s| s[prefix..].to_vec()).collect();
    Ok(Row {
        model: label.0.to_string(),
        steps: label.1.to_string(),
        sampler: label.2.to_string(),
        nll,
        d1: ngram::dist_n(&suffixes, 1),
        d2: ngram::dist_n(&suffixes, 2),
        d3: ngram::dist_n(&suffixes, 3),
        mauve: mauve::mauve_lite(&ref_suffixes, &suffixes),
        zipf: ngram::zipf_coefficient(&suffixes),
    })
}

fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "Model", "Steps", "Sampler", "AR-NLL", "Dist-1", "Dist-2", "Dist-3",
        "MAUVE-lite", "Zipf",
    ]);
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.steps.clone(),
            r.sampler.clone(),
            f(r.nll, 2),
            f(r.d1, 2),
            f(r.d2, 2),
            f(r.d3, 2),
            f(r.mauve, 2),
            f(r.zipf, 2),
        ]);
    }
    t.render()
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let n_max = ctx.n_steps();
    let n_samples = ctx.n_samples();
    let ds = ctx.dataset();
    let mut out = format!(
        "Table 3 — model comparison at varying step counts \
         (N_max={n_max}, {n_samples} samples/condition)\n\n"
    );

    for prefix in [PREFIX, 0usize] {
        let task = if prefix > 0 { "Prefix-32" } else { "Unconditional" };
        let mut rows: Vec<Row> = Vec::new();

        // Data row: held-out grammar samples vs themselves
        let refs = ds.val_prompts(777, n_samples);
        let held = ds.val_prompts(888, n_samples);
        rows.push(metrics_row(
            ctx,
            ("Data", "N/A", "N/A"),
            &held,
            &refs,
            prefix,
        )?);

        for fam in Family::all() {
            let store = ctx.store(fam.name())?;
            // lint:allow(family-seal): display-name lookup for the table header
            let sampler = match fam {
                Family::Ddlm => "Euler",
                Family::Ssd => "Simplex",
                Family::Plaid => "DDPM",
            };
            for &steps in &step_grid(n_max) {
                let mut opts = RunOpts::new(fam, n_samples, steps);
                opts.prefix_len = prefix;
                opts.seed = 9 + steps as u64;
                let rec = record_run(ctx, store.clone(), opts)?;
                let samples: Vec<Vec<i32>> = (0..n_samples)
                    .map(|i| rec.final_tokens(i).to_vec())
                    .collect();
                rows.push(metrics_row(
                    ctx,
                    (fam.name(), &steps.to_string(), sampler),
                    &samples,
                    &rec.references,
                    prefix,
                )?);
            }
        }

        // autoregressive baseline (stands in for GPT-2 / GPT-Neo rows)
        let ar_gen = ArGenerator::new(&ctx.rt, ctx.store("ar")?)?;
        let prompts = ds.val_prompts(777, n_samples);
        let ar_samples = ar_gen.generate(&prompts, prefix, 1.0, 99)?;
        rows.push(metrics_row(
            ctx,
            ("AR (evaluator)", "N/A", "ancestral"),
            &ar_samples,
            &prompts,
            prefix,
        )?);

        let _ = writeln!(out, "[{task}]\n{}", render(&rows));
    }
    out.push_str(
        "paper-shape check: DLMs trail the AR baseline on AR-NLL; more \
         steps help (then saturate); Zipf of samples near the data row's \
         value.\n",
    );
    Ok(out)
}
