//! Fig 8 — early-exit behaviour persists at sample length 256 (SSD and
//! Plaid; the paper's DDLM tops out at length 64, as does ours).
//!
//! The AR evaluator is compiled at L=64, so 256-token samples are scored
//! as the mean AR-NLL over four 64-token windows (documented
//! substitution).  L=256 step artifacts share the trained L=64 weights;
//! the positional table is tiled 4x (DESIGN.md §8).

use std::rc::Rc;

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::fig4::default_thresholds;
use super::Ctx;
use crate::eval::arnll::ArScorer;
use crate::halting::Kl;
use crate::models::store::ParamStore;
use crate::runtime::Tensor;
use crate::sampler::Family;
use crate::util::table::{f, Table};

const LONG: usize = 256;

/// Trained L=64 params adapted to the L=256 artifacts: tile `pos` 4x.
fn long_store(ctx: &Ctx, family: &str) -> Result<Rc<ParamStore>> {
    let base = ctx.store(family)?;
    let mut tensors = base.tensors.clone();
    let pos = base.get("pos")?.as_f32()?.to_vec();
    let d = ctx.rt.manifest.model.d_model;
    let l64 = ctx.rt.manifest.model.seq_len;
    let mut tiled = Vec::with_capacity(LONG * d);
    for i in 0..LONG {
        let src = (i % l64) * d;
        tiled.extend_from_slice(&pos[src..src + d]);
    }
    tensors.insert("pos".to_string(), Tensor::f32(&[LONG, d], tiled));
    Ok(Rc::new(ParamStore {
        family: family.to_string(),
        tensors,
    }))
}

fn windowed_nll(scorer: &ArScorer, samples: &[Vec<i32>]) -> Result<f64> {
    let mut windows = Vec::new();
    for s in samples {
        for chunk in s.chunks(64) {
            if chunk.len() == 64 {
                windows.push(chunk.to_vec());
            }
        }
    }
    Ok(scorer.mean_score(&windows, 0)? as f64)
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let scorer = ctx.scorer()?;
    let n_steps = ctx.n_steps();
    let (_, _, kl0) = default_thresholds(n_steps);
    let mut out = format!(
        "Fig 8 — AR-NLL vs exit step at sample length {LONG} \
         (N_max={n_steps}; windowed AR-NLL)\n\n"
    );
    for fam in [Family::Ssd, Family::Plaid] {
        let store = long_store(ctx, fam.name())?;
        let mut opts = RunOpts::new(fam, 4, n_steps);
        opts.seq_len = LONG;
        opts.seed = 8;
        let rec = record_run(ctx, store, opts)?;
        let mut table =
            Table::new(&["exit", "mean exit step", "AR-NLL (windowed)"]);
        for frac in [0.25, 0.5, 0.75, 0.9, 1.0] {
            let step = ((n_steps as f64 * frac) as usize).max(1);
            let samples: Vec<Vec<i32>> = (0..rec.traces.len())
                .map(|i| rec.tokens_at(i, step).to_vec())
                .collect();
            table.row(vec![
                format!("fixed:{step}"),
                step.to_string(),
                f(windowed_nll(&scorer, &samples)?, 3),
            ]);
        }
        let policy = Kl::new(kl0, n_steps / 4);
        let exits: Vec<usize> = (0..rec.traces.len())
            .map(|i| rec.exit_step(i, &policy))
            .collect();
        let mean_exit =
            exits.iter().sum::<usize>() as f64 / exits.len() as f64;
        let samples: Vec<Vec<i32>> = exits
            .iter()
            .enumerate()
            .map(|(i, &e)| rec.tokens_at(i, e).to_vec())
            .collect();
        table.row(vec![
            format!("kl:{kl0:.0e}"),
            f(mean_exit, 1),
            f(windowed_nll(&scorer, &samples)?, 3),
        ]);
        out.push_str(&format!("({})\n{}\n", fam.name(), table.render()));
    }
    out.push_str(
        "paper-shape check: the early-exit plateau persists at length \
         256 for both families.\n",
    );
    Ok(out)
}
