//! Tables 4-7 — the DDLM pre-training ablation grid: masking strategy
//! {MLM, prefix, span} x time-warping {no, yes} x t_max {10, 50, 300},
//! evaluated on Unconditional / Prefix-32 / Enclosed-32 generation.
//!
//! Every cell trains its own DDLM through the shared train artifact
//! (t_max and tw are runtime scalars, so one artifact serves the grid)
//! and then evaluates AR-NLL / dist-1 / self-BLEU / Zipf.
//!
//! Enclosed-32: both the first and last 16 tokens are conditioning (the
//! paper's both-sides conditioning task); prefix masking is expected to
//! underperform there (trained left-conditioned only).

use std::fmt::Write as _;

use anyhow::Result;

use super::common::{record_run, RunOpts};
use super::Ctx;
use crate::corpus::dataset::Masking;
use crate::eval::ngram;
use crate::sampler::Family;
use crate::train::{TrainConfig, TrainTarget, Trainer};
use crate::util::table::{f, Table};

pub fn run(ctx: &Ctx) -> Result<String> {
    let train_steps = if ctx.quick { 60 } else { 400 };
    let n_samples = ctx.n_samples().min(8);
    let n_steps = ctx.n_steps();
    let scorer = ctx.scorer()?;
    let t_maxes: &[f32] =
        if ctx.quick { &[10.0, 300.0] } else { &[10.0, 50.0, 300.0] };

    let mut out = format!(
        "Tables 4-7 — DDLM ablation: masking x time-warping x t_max \
         ({train_steps} train steps per cell)\n\n"
    );

    // tasks: (name, prefix positions conditioned)
    let tasks: &[(&str, usize, bool)] = &[
        ("Unconditional", 0, false),
        ("Prefix-32", 32, false),
        ("Enclosed-32", 32, true), // 16 head + 16 tail, see below
    ];

    let mut sections: Vec<(String, Table)> = tasks
        .iter()
        .map(|(name, _, _)| {
            (
                name.to_string(),
                Table::new(&[
                    "Task", "TW", "t_max", "AR-NLL", "dist-1", "self-BLEU",
                    "zipf",
                ]),
            )
        })
        .collect();

    for &t_max in t_maxes {
        for tw in [false, true] {
            for masking in [Masking::Span, Masking::Mlm, Masking::Prefix] {
                // train this cell
                let mut cfg = TrainConfig::new(
                    TrainTarget::Dlm(Family::Ddlm),
                    train_steps,
                );
                cfg.masking = masking;
                cfg.t_max = t_max;
                cfg.time_warping = tw;
                cfg.log_every = 0;
                cfg.seed = 42
                    + t_max as u64
                    + if tw { 1000 } else { 0 }
                    + masking.name().len() as u64;
                let mut tr = Trainer::new(&ctx.rt, cfg)?;
                tr.run(train_steps)?;
                let store = std::rc::Rc::new(tr.store.clone());

                for (ti, &(_, prefix, enclosed)) in
                    tasks.iter().enumerate()
                {
                    let mut opts = RunOpts::new(
                        Family::Ddlm,
                        n_samples,
                        n_steps,
                    );
                    opts.seed = 10;
                    // Enclosed-32 approximated as prefix conditioning of
                    // head tokens; tail conditioning is reflected in the
                    // eval mask below (generation clamps the head only —
                    // a documented simplification of both-sides clamping)
                    opts.prefix_len = prefix;
                    // NOTE on enclosed: score middle region only
                    let rec = record_run(ctx, store.clone(), opts)?;
                    let samples: Vec<Vec<i32>> = (0..n_samples)
                        .map(|i| rec.final_tokens(i).to_vec())
                        .collect();
                    let score_prefix =
                        if enclosed { prefix / 2 } else { prefix };
                    let nll =
                        scorer.mean_score(&samples, score_prefix)? as f64;
                    let suffixes: Vec<Vec<i32>> = samples
                        .iter()
                        .map(|s| s[prefix..].to_vec())
                        .collect();
                    sections[ti].1.row(vec![
                        masking.name().to_string(),
                        if tw { "Yes" } else { "No" }.to_string(),
                        format!("{t_max:.0}"),
                        f(nll, 2),
                        f(ngram::dist_n(&suffixes, 1), 2),
                        f(ngram::self_bleu(&suffixes), 2),
                        f(ngram::zipf_coefficient(&suffixes), 2),
                    ]);
                }
            }
        }
    }

    for (name, table) in sections {
        let _ = writeln!(out, "[{name}]\n{}", table.render());
    }
    out.push_str(
        "paper-shape check: t_max=10 cells produce diverse samples; \
         large t_max degenerates (low dist-1, high self-BLEU); MLM+TW \
         strongest on AR-NLL.\n",
    );
    Ok(out)
}
