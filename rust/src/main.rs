//! `repro` — launcher for the early-halting diffusion-LM stack.
//!
//! Subcommands:
//!   prepare   train all models + checkpoints the experiments need
//!   train     train one model (ablation knobs exposed)
//!   gen       generate text with a halting criterion, print it
//!   serve     run the TCP JSON-lines serving coordinator
//!   client    fire a request stream at a server, report latencies
//!   rebind    live-rebind a worker shard on a running server
//!   exp       run a paper experiment (fig1..fig8, tab1/3/4, headline)
//!   analyze   architectural lint over rust/src (the CI analyze stage)
//!
//! Global flags: --artifacts DIR (default artifacts), --runs DIR
//! (default runs), --quick (reduced sizes).

use std::rc::Rc;

use anyhow::{Context, Result};

use repro::coordinator::{start, Client, EngineConfig, GenRequest, Server};
use repro::corpus::dataset::Masking;
use repro::exp;
use repro::halting::{parse_policy, BoxedPolicy, HaltPolicy, NoHalt};
use repro::models::store::ParamStore;
use repro::predictor::PackingMode;
use repro::runtime::Runtime;
use repro::coordinator::Priority;
use repro::sampler::registry;
use repro::sampler::{Family, FamilyId, Session, SlotRequest};
use repro::train::{TrainConfig, TrainTarget, Trainer};
use repro::util::cli::Args;
use repro::util::fault;
use repro::util::log;

fn main() {
    log::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "prepare" => cmd_prepare(&args),
        "train" => cmd_train(&args),
        "gen" => cmd_gen(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "rebind" => cmd_rebind(&args),
        "exp" => cmd_exp(&args),
        "analyze" => cmd_analyze(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    // the family list is derived from the kernel registry, so new
    // pluggable kernels show up here without a hand-edited string
    let fams = Family::all()
        .iter()
        .map(|f| f.name())
        .collect::<Vec<_>>()
        .join("|");
    println!(
        "repro — early-halting diffusion-LM serving & training stack\n\
         \n\
         USAGE: repro <cmd> [--artifacts DIR] [--runs DIR] [options]\n\
         \n\
         prepare  --steps N (default 1200)      train ar + every DLM\n\
         \u{20}                                 family ({fams}), save\n\
         \u{20}                                 runs/<fam>.pbin and\n\
         \u{20}                                 ddlm_ck<k>.pbin checkpoints\n\
         train    --family {fams}|ar --steps N [--masking m]\n\
         \u{20}        [--tmax T] [--no-tw] [--out ckpt.pbin]\n\
         gen      --family F [--steps N] [--criterion SPEC] [--n 4]\n\
         \u{20}        [--prefix-len 32] [--noise 1.0]\n\
         serve    --family F [--addr 127.0.0.1:7411] [--batch 8]\n\
         \u{20}        [--workers 1] [--queue-depth 256]\n\
         \u{20}        [--fleet fam:batch,fam:batch,...|auto[,...]]\n\
         \u{20}        [--schedule fam:tmax:tmin,...]\n\
         \u{20}        [--family-queue-depth fam:N,...]\n\
         \u{20}        [--predictor] [--admission-control]\n\
         \u{20}        [--packing fifo|srpt] [--migrate]\n\
         \u{20}        [--artifact-cache-mb N]\n\
         \u{20}        [--journal PATH] [--retry-budget N]\n\
         \u{20}        [--brownout [MS]] [--faults SPEC]\n\
         \u{20}        (one worker per fleet entry — mixed families are\n\
         \u{20}        routed per request; without --fleet, N identical\n\
         \u{20}        workers of --family; bounded admission queue\n\
         \u{20}        rejects with a typed 'overloaded' error; legacy\n\
         \u{20}        wire supports priority, deadline_ms, family and\n\
         \u{20}        {{\"cmd\":\"cancel\",\"id\":..}}; v1 envelope frames\n\
         \u{20}        ({{\"v\":1,\"type\":...}}) add streamed progress\n\
         \u{20}        events and the graceful halt verb; --predictor\n\
         \u{20}        streams predicted_steps_remaining on v1 frames,\n\
         \u{20}        --admission-control rejects infeasible deadlines\n\
         \u{20}        with typed 'infeasible_deadline', --packing srpt\n\
         \u{20}        runs shortest-predicted work first; --fleet auto\n\
         \u{20}        starts the elastic supervisor that live-rebinds\n\
         \u{20}        idle shards toward starved families, --migrate\n\
         \u{20}        moves mostly-frozen slots to smaller live shards\n\
         \u{20}        mid-generation, --artifact-cache-mb bounds the\n\
         \u{20}        process-wide checkpoint cache; --journal write-\n\
         \u{20}        ahead-logs admissions and replays incomplete\n\
         \u{20}        work on restart, --retry-budget re-queues a dead\n\
         \u{20}        worker's in-flight requests, --brownout arms the\n\
         \u{20}        fleet-health degradation machine, --faults (or\n\
         \u{20}        REPRO_FAULTS) installs a deterministic fault\n\
         \u{20}        schedule 'point@N:kind[=ARG],...' — see API.md)\n\
         client   --addr HOST:PORT [--n 16] [--steps N] [--criterion SPEC]\n\
         \u{20}        [--priority high|normal|low] [--deadline-ms MS]\n\
         \u{20}        [--family {fams}] [--progress-every K]\n\
         rebind   --addr HOST:PORT --worker W [--family {fams}]\n\
         \u{20}        [--batch B] [--checkpoint PATH|--init]\n\
         \u{20}        (live drain→rebind→rejoin of one worker shard;\n\
         \u{20}        omitted fields keep the current binding)\n\
         exp      <id>|all  [--quick]   ids: {}\n\
         analyze  [--deny] [--report out.json] [--root DIR]\n\
         \u{20}        (architectural lint: panic-freedom, family-seal,\n\
         \u{20}        metrics-registry, wire-doc-drift, unsafe-hygiene;\n\
         \u{20}        --deny exits nonzero on unannotated violations)\n\
         \n\
         criterion SPEC is the halting-policy DSL: entropy:T, \n\
         patience:P[:TOL], kl:T[:MIN], fixed:N, none, norm:T[:P],\n\
         klslope:F[:W], and combinators any(p,...), all(p,...),\n\
         min(N,p), ema(A,p) — e.g. 'any(entropy:0.25,patience:20)'",
        exp::all_ids().join(" ")
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn runs_dir(args: &Args) -> String {
    args.get_or("runs", "runs").to_string()
}

fn parse_family(args: &Args) -> Result<Family> {
    let f = args.get_or("family", "ddlm");
    Family::parse(f).ok_or_else(|| anyhow::anyhow!("bad --family {f}"))
}

fn cmd_prepare(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let runs = runs_dir(args);
    std::fs::create_dir_all(&runs)?;
    let rt = Runtime::new(&dir)?;
    let steps = args.usize_or("steps", 1200);

    // AR evaluator first (everything else is scored with it)
    let mut cfg = TrainConfig::new(TrainTarget::Ar, steps);
    cfg.seed = 11;
    let mut tr = Trainer::new(&rt, cfg)?;
    tr.run(steps)?;
    tr.save_checkpoint(&format!("{runs}/ar.pbin"))?;
    println!("ar: final loss {:.3}", tr.losses.last().unwrap());

    // DDLM with intermediate checkpoints (Fig 1/2 need training colors)
    let mut cfg = TrainConfig::new(TrainTarget::Dlm(Family::Ddlm), steps);
    cfg.seed = 12;
    let mut tr = Trainer::new(&rt, cfg)?;
    let marks = [steps / 16, steps / 4, steps / 2, steps];
    let mut done = 0usize;
    for &mark in &marks {
        tr.run(mark - done)?;
        done = mark;
        tr.save_checkpoint(&format!("{runs}/ddlm_ck{mark}.pbin"))?;
        println!("ddlm ck{mark}: loss {:.3}", tr.losses.last().unwrap());
    }
    tr.save_checkpoint(&format!("{runs}/ddlm.pbin"))?;

    for fam in [Family::Ssd, Family::Plaid] {
        let mut cfg = TrainConfig::new(TrainTarget::Dlm(fam), steps);
        cfg.seed = 13;
        let mut tr = Trainer::new(&rt, cfg)?;
        tr.run(steps)?;
        tr.save_checkpoint(&format!("{runs}/{}.pbin", fam.name()))?;
        println!(
            "{}: final loss {:.3}",
            fam.name(),
            tr.losses.last().unwrap()
        );
    }
    println!("prepare done -> {runs}/");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    let fam_str = args.get_or("family", "ddlm");
    let steps = args.usize_or("steps", 400);
    let target = if fam_str == "ar" {
        TrainTarget::Ar
    } else {
        TrainTarget::Dlm(
            Family::parse(fam_str)
                .ok_or_else(|| anyhow::anyhow!("bad --family {fam_str}"))?,
        )
    };
    let mut cfg = TrainConfig::new(target, steps);
    cfg.t_max = args.f64_or("tmax", 10.0) as f32;
    cfg.time_warping = !args.flag("no-tw");
    if let Some(m) = args.get("masking") {
        cfg.masking = Masking::parse(m)
            .ok_or_else(|| anyhow::anyhow!("bad --masking {m}"))?;
    }
    cfg.base_lr = args.f64_or("lr", 3e-3) as f32;
    let mut tr = Trainer::new(&rt, cfg)?;
    tr.run(steps)?;
    let out = args.get_or("out", "model.pbin");
    tr.save_checkpoint(out)?;
    println!(
        "trained {fam_str} for {steps} steps; final loss {:.4}; saved {out}",
        tr.losses.last().unwrap()
    );
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let runs = runs_dir(args);
    let rt = Runtime::new(&dir)?;
    let fam = parse_family(args)?;
    let n_steps = args.usize_or("steps", 200);
    let n = args.usize_or("n", 4);
    let prefix_len = args.usize_or("prefix-len", 0);
    let noise = args.f64_or("noise", 1.0) as f32;
    let policy = match args.get("criterion") {
        Some(c) => parse_policy(c)
            .ok_or_else(|| anyhow::anyhow!("bad --criterion {c}"))?,
        None => Box::new(NoHalt) as BoxedPolicy,
    };

    let ckpt = format!("{runs}/{}.pbin", fam.name());
    let store = if std::path::Path::new(&ckpt).exists() {
        Rc::new(ParamStore::load(&ckpt, fam.name())?)
    } else {
        eprintln!("note: using untrained init params (run `repro prepare`)");
        Rc::new(ParamStore::load_init(&dir, fam.name())?)
    };
    let m = rt.manifest.model.clone();
    let batch = rt.manifest.resolve_step_batch(fam.name(), m.seq_len, n)?;
    let mut session = Session::new(&rt, fam, store, batch, m.seq_len)?;
    let ds = repro::corpus::dataset::Dataset::new(m.vocab, m.seq_len);
    let prompts = ds.val_prompts(args.u64_or("seed", 7), n);
    let tok = ds.grammar().tokenizer().clone();

    for group in (0..n).collect::<Vec<_>>().chunks(batch) {
        for (slot, &i) in group.iter().enumerate() {
            session.reset_slot(
                slot,
                &SlotRequest::new(
                    args.u64_or("seed", 7) + i as u64,
                    n_steps,
                    m.t_max,
                    m.t_min,
                )
                .noise(noise)
                .prefix(&prompts[i][..prefix_len]),
            )?;
        }
        for slot in group.len()..batch {
            session.release_slot(slot);
        }
        let mut policies: Vec<BoxedPolicy> =
            group.iter().map(|_| policy.clone()).collect();
        let mut exits = vec![usize::MAX; group.len()];
        for (slot, p) in policies.iter_mut().enumerate() {
            p.reset();
            if p.preflight().halted() {
                exits[slot] = 0;
                session.release_slot(slot);
            }
        }
        // skip device work entirely if every slot resolved in preflight
        let mut live_slots = exits.iter().any(|&e| e == usize::MAX);
        for step in 0..n_steps {
            if !live_slots {
                break;
            }
            let stats = session.step()?;
            let mut any_running = false;
            for (slot, _) in group.iter().enumerate() {
                if exits[slot] != usize::MAX {
                    continue; // already halted
                }
                if let Some(st) = stats[slot] {
                    if policies[slot].observe(step, &st).halted() {
                        exits[slot] = step + 1;
                        session.release_slot(slot);
                    } else {
                        any_running = true;
                    }
                }
            }
            live_slots = any_running;
        }
        for (slot, &i) in group.iter().enumerate() {
            let exit = if exits[slot] == usize::MAX {
                n_steps
            } else {
                exits[slot]
            };
            if exit == 0 {
                // preflight halt: no denoise step ran, the slot holds
                // raw initialization noise, not model output
                println!(
                    "--- sample {i} (exit 0/{n_steps} steps) ---\n\
                     (no steps executed)"
                );
                continue;
            }
            let toks = session.slot_output(slot);
            println!(
                "--- sample {i} (exit {exit}/{n_steps} steps) ---\n{}",
                tok.decode(&toks)
            );
        }
    }
    Ok(())
}

/// Parse a `--fleet` spec: comma-separated `family[:batch]` entries,
/// e.g. `ddlm:1,ddlm:8,ssd:8` — one worker shard per entry.  Family
/// names resolve through the open `sampler::registry`, so a kernel
/// registered at runtime is a valid shard.
fn parse_fleet(
    spec: &str,
    default_batch: usize,
) -> Result<Vec<(FamilyId, usize)>> {
    let mut out = Vec::new();
    for entry in spec.split(',').filter(|e| !e.is_empty()) {
        let (fam_str, batch) = match entry.split_once(':') {
            Some((f, b)) => (
                f,
                b.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad batch in --fleet entry {entry:?}")
                })?,
            ),
            None => (entry, default_batch),
        };
        let fam = registry::resolve(fam_str).ok_or_else(|| {
            anyhow::anyhow!("unknown family in --fleet entry {entry:?}")
        })?;
        out.push((fam, batch));
    }
    if out.is_empty() {
        anyhow::bail!("--fleet needs at least one family[:batch] entry");
    }
    Ok(out)
}

/// Parse a `--family-queue-depth` spec: comma-separated `family:N`
/// entries bounding each family's share of the admission queue (a full
/// family rejects with typed `overloaded` without blocking the rest).
fn parse_family_queue_bounds(
    spec: &str,
) -> Result<Vec<(FamilyId, usize)>> {
    let mut out = Vec::new();
    for entry in spec.split(',').filter(|e| !e.is_empty()) {
        let Some((fam_str, depth)) = entry.split_once(':') else {
            anyhow::bail!(
                "bad --family-queue-depth entry {entry:?} (want family:N)"
            );
        };
        let fam = registry::resolve(fam_str).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown family in --family-queue-depth entry {entry:?}"
            )
        })?;
        let depth = depth.parse::<usize>().map_err(|_| {
            anyhow::anyhow!(
                "bad depth in --family-queue-depth entry {entry:?}"
            )
        })?;
        out.push((fam, depth));
    }
    Ok(out)
}

/// Parse a `--schedule` spec: comma-separated `family:tmax:tmin`
/// entries overriding the fleet-wide schedule envelope per family
/// (surfaced to clients under `"families"` in the metrics snapshot).
fn parse_schedule_overrides(
    spec: &str,
) -> Result<Vec<(FamilyId, f32, f32)>> {
    let mut out = Vec::new();
    for entry in spec.split(',').filter(|e| !e.is_empty()) {
        let parts: Vec<&str> = entry.split(':').collect();
        let [fam_str, t_max, t_min] = parts.as_slice() else {
            anyhow::bail!(
                "bad --schedule entry {entry:?} (want family:tmax:tmin)"
            );
        };
        let fam = registry::resolve(fam_str).ok_or_else(|| {
            anyhow::anyhow!("unknown family in --schedule entry {entry:?}")
        })?;
        let parse = |s: &str| {
            s.parse::<f32>().map_err(|_| {
                anyhow::anyhow!("bad number in --schedule entry {entry:?}")
            })
        };
        out.push((fam, parse(t_max)?, parse(t_min)?));
    }
    Ok(out)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let runs = runs_dir(args);
    let fam = parse_family(args)?;
    let mut cfg = EngineConfig::new(&dir, fam);
    let batch = args.usize_or("batch", 8);
    let workers = args.usize_or("workers", 1).max(1);
    // elastic fleet: "--fleet auto" (optionally "auto,fam:batch,...")
    // starts the supervisor that live-rebinds idle shards toward
    // starved families; the remaining entries (or the --workers
    // default) are just the starting shape
    let (fleet_auto, fleet_spec) = match args.get("fleet") {
        Some("auto") => (true, None),
        Some(s) => match s.strip_prefix("auto,") {
            Some(rest) => (true, Some(rest)),
            None => (false, Some(s)),
        },
        None => (false, None),
    };
    cfg.fleet_auto = fleet_auto;
    cfg.migrate = args.flag("migrate");
    if let Some(mb) = args.get("artifact-cache-mb") {
        let mb: u64 = mb
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --artifact-cache-mb {mb}"))?;
        repro::runtime::artifact_cache::global()
            .set_budget(mb.saturating_mul(1024 * 1024));
    }
    cfg.worker_specs = match fleet_spec {
        // heterogeneous fleet: one worker per family[:batch] entry; the
        // default family (for requests without a `family` field) stays
        // --family, or the first fleet entry when --family isn't given
        Some(spec) => {
            let specs = parse_fleet(spec, batch)?;
            if args.get("family").is_none() {
                cfg.default_family = specs[0].0;
            }
            // a default family outside the fleet would silently reject
            // every family-less (pre-multi-family) request — refuse to
            // start misconfigured
            if !specs.iter().any(|&(f, _)| f == cfg.default_family) {
                anyhow::bail!(
                    "--family {} is not served by --fleet {spec} — \
                     requests without a family field could never be \
                     admitted",
                    cfg.default_family.name()
                );
            }
            specs
        }
        None => vec![(fam.into(), batch); workers],
    };
    cfg.queue_depth = args.usize_or("queue-depth", 256);
    if let Some(spec) = args.get("schedule") {
        cfg.schedule_overrides = parse_schedule_overrides(spec)?;
    }
    if let Some(spec) = args.get("family-queue-depth") {
        cfg.family_queue_bounds = parse_family_queue_bounds(spec)?;
    }
    // completeness-predictor gates (each independent, all default off):
    // --predictor puts predicted_steps_remaining / predicted_total_steps
    // on v1 frames, --admission-control rejects infeasible deadlines,
    // --packing srpt orders same-priority work shortest-predicted-first
    cfg.predictor.enabled = args.flag("predictor");
    cfg.predictor.admission = args.flag("admission-control");
    if let Some(p) = args.get("packing") {
        cfg.predictor.packing = PackingMode::parse(p)
            .ok_or_else(|| anyhow::anyhow!("bad --packing {p} (fifo|srpt)"))?;
    }
    // chaos hardening (each independent, all default off): --journal
    // write-ahead-logs admissions and replays incomplete work on
    // restart, --retry-budget re-queues a dead worker's in-flight
    // requests, --brownout arms the fleet-health degradation machine,
    // --faults installs a deterministic fault-injection schedule
    cfg.journal_path = args.get("journal").map(str::to_string);
    cfg.retry_budget = args.usize_or("retry-budget", 0) as u32;
    if args.flag("brownout") {
        cfg.brownout_recover_ms = Some(1500);
    } else if let Some(ms) = args.get("brownout") {
        cfg.brownout_recover_ms = Some(ms.parse().map_err(|_| {
            anyhow::anyhow!(
                "bad --brownout {ms} (want a recovery window in ms)"
            )
        })?);
    }
    if let Some(spec) = args.get("faults") {
        fault::install(spec)
            .map_err(|e| anyhow::anyhow!("bad --faults: {e}"))?;
    } else if let Err(e) = fault::install_from_env() {
        anyhow::bail!("bad REPRO_FAULTS: {e}");
    }
    cfg.discover_checkpoints(&runs);
    let shards = cfg
        .worker_specs
        .iter()
        .map(|(f, b)| format!("{}:b{b}", f.name()))
        .collect::<Vec<_>>()
        .join(", ");
    let default_family = cfg.default_family;
    let predictor_note = if cfg.predictor.active() {
        format!(
            ", predictor[wire:{} admission:{} packing:{}]",
            cfg.predictor.enabled,
            cfg.predictor.admission,
            cfg.predictor.packing.name()
        )
    } else {
        String::new()
    };
    let elastic_note = match (cfg.fleet_auto, cfg.migrate) {
        (true, _) => ", fleet:auto",
        (false, true) => ", migrate",
        (false, false) => "",
    };
    let chaos_note = {
        let mut parts = Vec::new();
        if cfg.journal_path.is_some() {
            parts.push("journal".to_string());
        }
        if cfg.retry_budget > 0 {
            parts.push(format!("retry:{}", cfg.retry_budget));
        }
        if cfg.brownout_recover_ms.is_some() {
            parts.push("brownout".to_string());
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!(", {}", parts.join("+"))
        }
    };
    let (engine, join) = start(cfg);
    let addr = args.get_or("addr", "127.0.0.1:7411");
    let mut server = Server::start(addr, engine)?;
    println!(
        "serving [{shards}] on {} (default family {}{predictor_note}\
         {elastic_note}{chaos_note})",
        server.addr,
        default_family.name()
    );
    let res = join.join().unwrap().context("engine");
    server.stop();
    res
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7411");
    let n = args.usize_or("n", 16);
    let steps = args.usize_or("steps", 200);
    let crit = args.get_or("criterion", "none").to_string();
    let priority = Priority::parse(args.get_or("priority", "normal"))
        .ok_or_else(|| anyhow::anyhow!("bad --priority"))?;
    let deadline_ms = args.get("deadline-ms").map(|s| {
        s.parse::<f64>()
            .map_err(|_| anyhow::anyhow!("bad --deadline-ms"))
    });
    let deadline_ms = deadline_ms.transpose()?;
    // optional family routing (heterogeneous fleets); omitted = the
    // server's default family.  Resolution goes through the open
    // registry, so runtime-registered families are addressable too.
    let family = match args.get("family") {
        Some(f) => Some(
            registry::resolve(f)
                .ok_or_else(|| anyhow::anyhow!("bad --family {f}"))?,
        ),
        None => None,
    };
    // subscribe to streamed per-step completeness events (v1 envelope)
    let progress_every = match args.get("progress-every") {
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("bad --progress-every (want a step count)")
        })?),
        None => None,
    };
    let mut client = Client::connect(addr)?;
    let t0 = std::time::Instant::now();
    let mut total_steps = 0usize;
    for i in 0..n {
        let mut req = GenRequest::new(i as u64, steps);
        req.policy = parse_policy(&crit)
            .ok_or_else(|| anyhow::anyhow!("bad --criterion"))?;
        req.priority = priority;
        req.deadline_ms = deadline_ms;
        req.family = family;
        req.progress_every = progress_every;
        let resp = client.generate_with(&req, |ev| {
            println!(
                "req {i}: progress {}/{} — entropy {:.3}, kl {:.6}, \
                 switches {:.1}",
                ev.step,
                ev.steps_budget,
                ev.stats.entropy,
                ev.stats.kl,
                ev.stats.switches
            );
        })?;
        total_steps += resp.steps_executed;
        println!(
            "req {i}: {} steps, {:.1} ms{}",
            resp.steps_executed,
            resp.latency_ms,
            match &resp.halt_reason {
                Some(r) => format!(" (halted early: {r})"),
                None => String::new(),
            }
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "total: {n} requests in {wall:.2}s ({:.2} req/s), mean {:.1} \
         steps/req",
        n as f64 / wall,
        total_steps as f64 / n as f64
    );
    println!("server metrics: {}", client.metrics()?.encode());
    Ok(())
}

/// Operator verb: live-rebind one worker shard on a running server
/// (drain → rebuild under the new binding → rejoin, zero dropped
/// requests).  Omitted fields keep the worker's current value;
/// `--init` drops to init params instead of a checkpoint.
fn cmd_rebind(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7411");
    let worker = args
        .get("worker")
        .ok_or_else(|| anyhow::anyhow!("rebind needs --worker N"))?
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("bad --worker (want a shard index)"))?;
    let batch = match args.get("batch") {
        Some(b) => Some(
            b.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --batch {b}"))?,
        ),
        None => None,
    };
    let checkpoint = if args.flag("init") {
        Some("") // empty path = drop to init params
    } else {
        args.get("checkpoint")
    };
    let mut client = Client::connect(addr)?;
    let ack = client.rebind(worker, args.get("family"), batch, checkpoint)?;
    if !ack.ok {
        anyhow::bail!(
            "rebind refused: {}",
            ack.message.as_deref().unwrap_or("unknown error")
        );
    }
    println!(
        "worker {worker} rebound -> {}:b{} ({} in-flight drained and \
         requeued, {:.1} ms)",
        ack.family.as_deref().unwrap_or("?"),
        ack.batch.unwrap_or(0),
        ack.drained.unwrap_or(0),
        ack.rebind_ms.unwrap_or(0.0)
    );
    Ok(())
}

/// Static-analysis gate: run the architectural lint over the tree and
/// report (or, with `--deny`, fail on) unannotated violations.  See
/// API.md "Invariants & static analysis" for the check catalogue and
/// the `lint:allow` grammar.
fn cmd_analyze(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    let report = repro::analysis::analyze_tree(&root)?;
    print!("{}", report.render_text());
    if let Some(path) = args.get("report") {
        std::fs::write(path, report.to_json().encode())
            .with_context(|| format!("write {path}"))?;
        println!("analyze: JSON report written to {path}");
    }
    if args.flag("deny") && !report.violations.is_empty() {
        anyhow::bail!(
            "{} lint violation(s) — fix them or add a justified \
             `// lint:allow(<check>): <reason>`",
            report.violations.len()
        );
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let ctx = exp::Ctx::new(
        &artifacts_dir(args),
        &runs_dir(args),
        args.flag("quick"),
    )?;
    let ids: Vec<&str> = if id == "all" {
        exp::all_ids().to_vec()
    } else {
        vec![id]
    };
    std::fs::create_dir_all("results").ok();
    for id in ids {
        let t0 = std::time::Instant::now();
        let text = exp::run(&ctx, id)?;
        println!("{text}");
        println!("[{id} took {:.1}s]\n", t0.elapsed().as_secs_f64());
        std::fs::write(format!("results/{id}.txt"), &text).ok();
    }
    Ok(())
}
