//! Early-exit criteria — the paper's contribution as a library
//! (Algorithms 1-3 + the fixed-step baseline).
//!
//! Each criterion consumes the per-slot statistics the step artifacts
//! compute on-device (entropy of p(x|X(t),t), KL vs the previous step,
//! argmax token switches) and decides whether that slot's generation can
//! stop.  State is per-request (`CriterionState`), so the coordinator can
//! run a different criterion/threshold per request in the same batch.

/// Per-step statistics for one batch slot (produced by the step artifact).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub entropy: f32,
    pub kl: f32,
    pub switches: f32,
    pub norm_x0: f32,
    pub norm_x: f32,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Criterion {
    /// Algorithm 1: halt when entropy <= threshold.
    Entropy { threshold: f32 },
    /// Algorithm 2: halt after `patience` consecutive steps whose argmax
    /// tokens changed at most `tolerance` positions.
    Patience { patience: usize, tolerance: f32 },
    /// Algorithm 3: halt when KL(p_t || p_{t-1}) <= threshold, after at
    /// least `min_steps` steps (paper: min_steps ~ 0.25 N_max).
    Kl { threshold: f32, min_steps: usize },
    /// Fixed-step baseline: halt unconditionally at `step`.
    Fixed { step: usize },
    /// Never halt (full-schedule baseline).
    None,
}

impl Criterion {
    pub fn name(&self) -> &'static str {
        match self {
            Criterion::Entropy { .. } => "entropy",
            Criterion::Patience { .. } => "patience",
            Criterion::Kl { .. } => "kl",
            Criterion::Fixed { .. } => "fixed",
            Criterion::None => "none",
        }
    }

    /// Parse "entropy:0.5", "patience:20", "kl:1e-3:250", "fixed:600",
    /// "none" (CLI/config syntax).
    pub fn parse(s: &str) -> Option<Criterion> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "none" => Some(Criterion::None),
            "entropy" => Some(Criterion::Entropy {
                threshold: parts.get(1)?.parse().ok()?,
            }),
            "patience" => Some(Criterion::Patience {
                patience: parts.get(1)?.parse().ok()?,
                tolerance: parts
                    .get(2)
                    .map(|t| t.parse().ok())
                    .unwrap_or(Some(0.0))?,
            }),
            "kl" => Some(Criterion::Kl {
                threshold: parts.get(1)?.parse().ok()?,
                min_steps: parts
                    .get(2)
                    .map(|t| t.parse().ok())
                    .unwrap_or(Some(0))?,
            }),
            "fixed" => Some(Criterion::Fixed {
                step: parts.get(1)?.parse().ok()?,
            }),
            _ => None,
        }
    }
}

/// Mutable per-request evaluation state.
#[derive(Clone, Debug, Default)]
pub struct CriterionState {
    /// consecutive low-change steps (Patience)
    run: usize,
    /// steps observed so far
    steps: usize,
}

impl CriterionState {
    pub fn reset(&mut self) {
        *self = CriterionState::default();
    }

    /// Feed one step's statistics; returns true when the criterion fires.
    /// `step` is the 0-based index of the step that just completed.
    pub fn observe(&mut self, crit: &Criterion, stats: &StepStats) -> bool {
        let step = self.steps;
        self.steps += 1;
        match *crit {
            Criterion::None => false,
            Criterion::Fixed { step: s } => step + 1 >= s,
            Criterion::Entropy { threshold } => stats.entropy <= threshold,
            Criterion::Kl { threshold, min_steps } => {
                // the first step has no meaningful previous distribution
                step > 0 && self.steps >= min_steps && stats.kl <= threshold
            }
            Criterion::Patience { patience, tolerance } => {
                if step > 0 && stats.switches <= tolerance {
                    self.run += 1;
                } else {
                    self.run = 0;
                }
                self.run >= patience
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(entropy: f32, kl: f32, switches: f32) -> StepStats {
        StepStats {
            entropy,
            kl,
            switches,
            ..Default::default()
        }
    }

    #[test]
    fn entropy_fires_below_threshold() {
        let c = Criterion::Entropy { threshold: 0.5 };
        let mut s = CriterionState::default();
        assert!(!s.observe(&c, &stats(2.0, 1.0, 5.0)));
        assert!(!s.observe(&c, &stats(0.6, 1.0, 5.0)));
        assert!(s.observe(&c, &stats(0.4, 1.0, 5.0)));
    }

    #[test]
    fn kl_respects_min_steps_and_first_step() {
        let c = Criterion::Kl {
            threshold: 1e-3,
            min_steps: 3,
        };
        let mut s = CriterionState::default();
        // step 0: never fires (no previous distribution)
        assert!(!s.observe(&c, &stats(1.0, 0.0, 0.0)));
        assert!(!s.observe(&c, &stats(1.0, 0.0, 0.0))); // steps=2 < 3
        assert!(s.observe(&c, &stats(1.0, 1e-4, 0.0))); // steps=3 >= 3
    }

    #[test]
    fn patience_requires_consecutive_run() {
        let c = Criterion::Patience {
            patience: 3,
            tolerance: 0.0,
        };
        let mut s = CriterionState::default();
        assert!(!s.observe(&c, &stats(0.0, 0.0, 0.0))); // step 0 ignored
        assert!(!s.observe(&c, &stats(0.0, 0.0, 0.0))); // run=1
        assert!(!s.observe(&c, &stats(0.0, 0.0, 2.0))); // broken -> 0
        assert!(!s.observe(&c, &stats(0.0, 0.0, 0.0))); // run=1
        assert!(!s.observe(&c, &stats(0.0, 0.0, 0.0))); // run=2
        assert!(s.observe(&c, &stats(0.0, 0.0, 0.0))); // run=3 -> fire
    }

    #[test]
    fn fixed_fires_exactly_at_step() {
        let c = Criterion::Fixed { step: 2 };
        let mut s = CriterionState::default();
        assert!(!s.observe(&c, &stats(9.0, 9.0, 9.0)));
        assert!(s.observe(&c, &stats(9.0, 9.0, 9.0)));
    }

    #[test]
    fn none_never_fires_property() {
        let mut s = CriterionState::default();
        let mut r = crate::util::prng::Prng::new(3);
        for _ in 0..500 {
            let st = stats(
                r.uniform_f32(),
                r.uniform_f32() * 1e-6,
                0.0,
            );
            assert!(!s.observe(&Criterion::None, &st));
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Criterion::parse("entropy:0.5"),
            Some(Criterion::Entropy { threshold: 0.5 })
        );
        assert_eq!(
            Criterion::parse("patience:20"),
            Some(Criterion::Patience {
                patience: 20,
                tolerance: 0.0
            })
        );
        assert_eq!(
            Criterion::parse("kl:0.001:250"),
            Some(Criterion::Kl {
                threshold: 0.001,
                min_steps: 250
            })
        );
        assert_eq!(
            Criterion::parse("fixed:600"),
            Some(Criterion::Fixed { step: 600 })
        );
        assert_eq!(Criterion::parse("none"), Some(Criterion::None));
        assert_eq!(Criterion::parse("bogus:1"), None);
        assert_eq!(Criterion::parse("entropy"), None);
    }

    #[test]
    fn patience_tolerance_allows_small_changes() {
        let c = Criterion::Patience {
            patience: 2,
            tolerance: 1.5,
        };
        let mut s = CriterionState::default();
        s.observe(&c, &stats(0.0, 0.0, 9.0)); // step 0
        assert!(!s.observe(&c, &stats(0.0, 0.0, 1.0))); // within tol, run=1
        assert!(s.observe(&c, &stats(0.0, 0.0, 0.0))); // run=2 -> fire
    }
}
