//! Halting policies — the paper's early-exit contribution as an *open*,
//! composable API (Algorithms 1-3, the fixed-step baseline, and policies
//! the original closed enum could not express).
//!
//! A [`HaltPolicy`] consumes the per-slot statistics the step artifacts
//! compute on-device ([`StepStats`]) and decides after each step whether
//! that slot's generation can stop.  Policies are per-request values, so
//! the coordinator can run a different policy per request in the same
//! batch.  A [`Decision::Halt`] carries the *reason* (the primitive that
//! fired), which flows into the serving metrics' per-reason counters.
//!
//! Policies compose: [`Any`]/[`All`] combine sub-policies, [`MinSteps`]
//! guards against premature exits, [`Ema`] smooths the raw signals.  The
//! spec DSL (`parse_policy`) round-trips every policy through a string
//! form used by the CLI and the JSON wire protocol, e.g.
//! `"any(entropy:0.25,min(50,kl:0.0006:0))"`; the legacy enum-era specs
//! (`entropy:0.5`, `patience:20`, `kl:1e-3:250`, `fixed:600`, `none`)
//! parse unchanged.

mod combinators;
mod policies;
mod spec;

pub use combinators::{All, Any, Ema, MinSteps};
pub use policies::{
    Entropy, Fixed, Kl, KlSlope, NoHalt, NormStable, Patience, TokEntropy,
    TokStab,
};
pub use spec::{parse_policy, PrimitiveCtor, Registry};

/// Per-step statistics for one batch slot (produced by the step artifact).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub entropy: f32,
    pub kl: f32,
    pub switches: f32,
    pub norm_x0: f32,
    pub norm_x: f32,
}

/// Per-position statistics for one batch slot (format-3 artifacts download
/// these as lanes of the fused stat tensor).  All slices have length L.
///
/// `entropy[p]` is H(p_p) at position p, `changed[p]` is 1.0 where the
/// argmax token changed this step, and `frozen[p]` is 1.0 where the
/// position is already frozen (policies should not re-freeze those).
#[derive(Clone, Copy, Debug)]
pub struct TokenStats<'a> {
    pub entropy: &'a [f32],
    pub changed: &'a [f32],
    pub frozen: &'a [f32],
}

/// Outcome of feeding one step's statistics to a policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    Continue,
    /// Stop generating; `reason` names the primitive policy that fired
    /// (combinators propagate the inner reason).
    Halt { reason: &'static str },
    /// Freeze the positions where `mask[p]` is true (token-level early
    /// stopping): the session clamps them on-device like a
    /// dynamically-grown prefix, and generation continues for the rest.
    /// A slot whose positions are all frozen halts with reason
    /// `"all_frozen"`.
    Freeze { mask: Vec<bool> },
}

impl Decision {
    pub fn halted(&self) -> bool {
        matches!(self, Decision::Halt { .. })
    }

    pub fn reason(&self) -> Option<&'static str> {
        match self {
            Decision::Halt { reason } => Some(reason),
            _ => None,
        }
    }

    /// The freeze mask, if this decision freezes positions.
    pub fn freeze_mask(&self) -> Option<&[bool]> {
        match self {
            Decision::Freeze { mask } => Some(mask),
            _ => None,
        }
    }
}

/// An early-exit policy: per-request mutable state + the halting rule.
///
/// Contract: `observe` is called once per executed denoise step with the
/// 0-based index of the step that just completed; calls are consecutive
/// from 0 between `reset`s.  Implementations must be cheap — `observe`
/// sits on the serving hot path between device steps.
pub trait HaltPolicy: Send {
    /// Feed one completed step's statistics; decide whether to stop.
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision;

    /// Feed one step's statistics *with* per-position signals.  The
    /// engine calls this (instead of `observe`) when token lanes are
    /// available — format-3 artifacts on a kernel that supports token
    /// halting.  Token-level policies override it to return
    /// [`Decision::Freeze`]; the default ignores the lanes, so
    /// sequence-level policies behave identically on both call paths.
    fn observe_tokens(
        &mut self,
        step: usize,
        stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        let _ = tok;
        self.observe(step, stats)
    }

    /// Clear per-request state (policies are cloned into batch slots and
    /// reset on admission).
    fn reset(&mut self) {}

    /// Short primitive name (`"entropy"`, `"any"`, ...) used for display
    /// and halt-reason attribution.
    fn name(&self) -> &'static str;

    /// Canonical spec string; `parse_policy(p.to_spec())` reconstructs an
    /// equivalent policy (single source of truth for the wire format).
    fn to_spec(&self) -> String;

    /// Decide before any step has run.  A `fixed:0` budget resolves here,
    /// letting the engine answer without occupying a batch slot.
    fn preflight(&self) -> Decision {
        Decision::Continue
    }

    /// Clone into a boxed policy (object-safe `Clone`).
    fn clone_box(&self) -> BoxedPolicy;
}

/// Owned, type-erased policy — what requests and batch slots hold.
pub type BoxedPolicy = Box<dyn HaltPolicy>;

impl Clone for BoxedPolicy {
    fn clone(&self) -> BoxedPolicy {
        self.clone_box()
    }
}

impl std::fmt::Debug for BoxedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "HaltPolicy({})", self.to_spec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn stats(entropy: f32, kl: f32, switches: f32) -> StepStats {
        StepStats {
            entropy,
            kl,
            switches,
            ..Default::default()
        }
    }

    /// Drive a policy over a trace; return the 1-based exit step and
    /// reason, or None if it never fires.
    pub(crate) fn drive(
        policy: &mut dyn HaltPolicy,
        trace: &[StepStats],
    ) -> Option<(usize, &'static str)> {
        policy.reset();
        if let Decision::Halt { reason } = policy.preflight() {
            return Some((0, reason));
        }
        for (i, st) in trace.iter().enumerate() {
            if let Decision::Halt { reason } = policy.observe(i, st) {
                return Some((i + 1, reason));
            }
        }
        None
    }

    #[test]
    fn entropy_fires_below_threshold() {
        let mut p = Entropy::new(0.5);
        assert!(!p.observe(0, &stats(2.0, 1.0, 5.0)).halted());
        assert!(!p.observe(1, &stats(0.6, 1.0, 5.0)).halted());
        assert_eq!(
            p.observe(2, &stats(0.4, 1.0, 5.0)),
            Decision::Halt { reason: "entropy" }
        );
    }

    #[test]
    fn kl_respects_min_steps_and_first_step() {
        let mut p = Kl::new(1e-3, 3);
        // step 0: never fires (no previous distribution)
        assert!(!p.observe(0, &stats(1.0, 0.0, 0.0)).halted());
        assert!(!p.observe(1, &stats(1.0, 0.0, 0.0)).halted()); // 2 < 3
        assert_eq!(
            p.observe(2, &stats(1.0, 1e-4, 0.0)),
            Decision::Halt { reason: "kl" }
        );
    }

    #[test]
    fn patience_requires_consecutive_run() {
        let mut p = Patience::new(3, 0.0);
        assert!(!p.observe(0, &stats(0.0, 0.0, 0.0)).halted()); // step 0 ignored
        assert!(!p.observe(1, &stats(0.0, 0.0, 0.0)).halted()); // run=1
        assert!(!p.observe(2, &stats(0.0, 0.0, 2.0)).halted()); // broken -> 0
        assert!(!p.observe(3, &stats(0.0, 0.0, 0.0)).halted()); // run=1
        assert!(!p.observe(4, &stats(0.0, 0.0, 0.0)).halted()); // run=2
        assert_eq!(
            p.observe(5, &stats(0.0, 0.0, 0.0)),
            Decision::Halt { reason: "patience" }
        );
    }

    #[test]
    fn patience_tolerance_allows_small_changes() {
        let mut p = Patience::new(2, 1.5);
        assert!(!p.observe(0, &stats(0.0, 0.0, 9.0)).halted());
        assert!(!p.observe(1, &stats(0.0, 0.0, 1.0)).halted()); // within tol
        assert!(p.observe(2, &stats(0.0, 0.0, 0.0)).halted());
    }

    #[test]
    fn reset_clears_patience_run() {
        let mut p = Patience::new(2, 0.0);
        p.observe(0, &stats(0.0, 0.0, 0.0));
        p.observe(1, &stats(0.0, 0.0, 0.0));
        p.reset();
        assert!(!p.observe(0, &stats(0.0, 0.0, 0.0)).halted());
        assert!(!p.observe(1, &stats(0.0, 0.0, 0.0)).halted());
        assert!(p.observe(2, &stats(0.0, 0.0, 0.0)).halted());
    }

    #[test]
    fn fixed_fires_exactly_at_step() {
        let mut p = Fixed::new(2);
        assert!(!p.observe(0, &stats(9.0, 9.0, 9.0)).halted());
        assert_eq!(
            p.observe(1, &stats(9.0, 9.0, 9.0)),
            Decision::Halt { reason: "fixed" }
        );
    }

    #[test]
    fn fixed_zero_resolves_in_preflight() {
        // a zero-step budget halts before any step runs — the engine
        // answers such requests without occupying a batch slot
        let p = Fixed::new(0);
        assert_eq!(p.preflight(), Decision::Halt { reason: "fixed" });
        assert_eq!(Fixed::new(1).preflight(), Decision::Continue);
        // and the DSL accepts it with the same semantics
        let q = parse_policy("fixed:0").unwrap();
        assert_eq!(q.preflight(), Decision::Halt { reason: "fixed" });
        assert_eq!(drive(&mut *q.clone(), &[stats(1.0, 1.0, 1.0)]), Some((0, "fixed")));
    }

    #[test]
    fn none_never_fires_property() {
        let mut p = NoHalt;
        let mut r = crate::util::prng::Prng::new(3);
        for i in 0..500 {
            let st = stats(r.uniform_f32(), r.uniform_f32() * 1e-6, 0.0);
            assert!(!p.observe(i, &st).halted());
        }
    }

    #[test]
    fn norm_stable_fires_when_norms_converge() {
        // norm_x relaxes toward norm_x0; rel gap <= 5% for 3 steps
        let mut p = NormStable::new(0.05, 3);
        let mut trace = Vec::new();
        for i in 0..20 {
            trace.push(StepStats {
                norm_x0: 8.0,
                norm_x: 8.0 + 8.0 * (-(i as f32)).exp(),
                ..Default::default()
            });
        }
        // gap/norm_x0 = e^{-i}: <=0.05 from i=3 on; 3 consecutive -> i=5
        assert_eq!(drive(&mut p, &trace), Some((6, "norm")));
    }

    #[test]
    fn kl_slope_fires_when_decay_flattens() {
        // kl halves for 6 steps (rel decrease 0.5), then flattens to a
        // 1% decay; flat threshold 5% with window 3
        let mut p = KlSlope::new(0.05, 3);
        let mut trace = Vec::new();
        let mut kl = 1.0f32;
        for i in 0..20 {
            kl *= if i < 6 { 0.5 } else { 0.99 };
            trace.push(stats(1.0, kl, 1.0));
        }
        // steps 7.. have rel decrease 0.01 <= 0.05; window 3 -> step 9
        // (observe index 8), 1-based exit 9... first flat step is i=6
        // (kl[6]=kl[5]*0.99), run=1 at i=6, 2 at i=7, 3 at i=8 -> exit 9
        assert_eq!(drive(&mut p, &trace), Some((9, "klslope")));
    }

    #[test]
    fn any_fires_on_first_inner_with_its_reason() {
        let mut p = Any::new(vec![
            Box::new(Entropy::new(0.5)),
            Box::new(Fixed::new(4)),
        ]);
        let trace = vec![stats(1.0, 1.0, 1.0); 10];
        assert_eq!(drive(&mut p, &trace), Some((4, "fixed")));
        let mut p = Any::new(vec![
            Box::new(Entropy::new(0.5)),
            Box::new(Fixed::new(4)),
        ]);
        let trace = vec![stats(0.1, 1.0, 1.0); 10];
        assert_eq!(drive(&mut p, &trace), Some((1, "entropy")));
    }

    #[test]
    fn any_keeps_feeding_stateful_legs_while_suppressed() {
        // min(20, any(entropy, patience)): the entropy leg fires during
        // steps 9-19 but the guard suppresses those halts; the patience
        // leg must keep observing through them so its run is intact the
        // moment the guard lifts
        let mut trace = Vec::new();
        for i in 0..40 {
            trace.push(stats(
                if (8..=18).contains(&i) { 0.1 } else { 2.0 },
                1.0,
                if i >= 5 { 0.0 } else { 9.0 },
            ));
        }
        let mut p = MinSteps::new(
            20,
            Box::new(Any::new(vec![
                Box::new(Entropy::new(0.5)),
                Box::new(Patience::new(10, 0.0)),
            ])),
        );
        // patience run: 1 at step 5, 10 at step 14, 15 at step 19 — the
        // guard lifts at step 20 (index 19) and patience fires there
        assert_eq!(drive(&mut p, &trace), Some((20, "patience")));
    }

    #[test]
    fn all_waits_for_every_inner_latched() {
        // entropy fires at step 3, fixed at step 5; All fires at 5 even
        // though entropy's signal is no longer low then (latched)
        let mut trace = vec![stats(1.0, 1.0, 1.0); 10];
        trace[2].entropy = 0.1; // only step 2 is low-entropy
        let mut p = All::new(vec![
            Box::new(Entropy::new(0.5)),
            Box::new(Fixed::new(5)),
        ]);
        assert_eq!(drive(&mut p, &trace), Some((5, "fixed")));
    }

    #[test]
    fn all_keeps_primitive_reason_under_suppression() {
        // the conjunction completes at step 2 (reason "fixed") but the
        // guard suppresses it until step 6 — the latched primitive
        // reason must survive, never a synthetic "all"
        let p = parse_policy("min(6,all(entropy:1000000000,fixed:2))").unwrap();
        let trace = vec![stats(1.0, 1.0, 1.0); 10];
        assert_eq!(drive(&mut *p.clone(), &trace), Some((6, "fixed")));
    }

    #[test]
    fn min_steps_guard_suppresses_early_halts() {
        let mut p = MinSteps::new(6, Box::new(Entropy::new(0.5)));
        let trace = vec![stats(0.1, 1.0, 1.0); 10];
        assert_eq!(drive(&mut p, &trace), Some((6, "entropy")));
        // preflight passes through only with min == 0
        assert!(!MinSteps::new(1, Box::new(Fixed::new(0))).preflight().halted());
        assert!(MinSteps::new(0, Box::new(Fixed::new(0))).preflight().halted());
    }

    #[test]
    fn ema_smoothing_delays_noisy_crossing() {
        // raw entropy alternates 0.1 / 2.0: raw policy fires at step 1,
        // the smoothed signal stays above threshold
        let mut trace = Vec::new();
        for i in 0..20 {
            trace.push(stats(if i % 2 == 0 { 0.1 } else { 2.0 }, 1.0, 1.0));
        }
        let mut raw = Entropy::new(0.5);
        assert_eq!(drive(&mut raw, &trace), Some((1, "entropy")));
        let mut sm = Ema::new(0.2, Box::new(Entropy::new(0.5)));
        // EMA starts at 0.1 (first sample) but relaxes toward the ~1.05
        // mean; after step 1 it never re-crosses 0.5
        let exit = drive(&mut sm, &trace);
        assert_eq!(exit, Some((1, "entropy"))); // first sample seeds EMA low
        // seeding with the high value keeps it above threshold for good
        let mut sm = Ema::new(0.2, Box::new(Entropy::new(0.5)));
        let mut shifted = trace.clone();
        shifted.rotate_left(1); // starts at 2.0
        assert_eq!(drive(&mut sm, &shifted), None);
    }

    #[test]
    fn legacy_specs_parse_to_equivalent_policies() {
        // behavior equivalence with the removed Criterion enum
        let trace: Vec<StepStats> =
            (0..100).map(|i| stats(2.0 - 0.03 * i as f32, 0.1, 1.0)).collect();
        // entropy <= 0.5 at i where 2 - 0.03i <= 0.5 -> i >= 50
        let p = parse_policy("entropy:0.5").unwrap();
        assert_eq!(drive(&mut *p.clone(), &trace), Some((51, "entropy")));
        assert_eq!(drive(&mut *parse_policy("fixed:600").unwrap(), &trace), None);
        assert_eq!(
            drive(&mut *parse_policy("fixed:60").unwrap(), &trace),
            Some((60, "fixed"))
        );
        assert_eq!(drive(&mut *parse_policy("none").unwrap(), &trace), None);
        assert_eq!(
            drive(&mut *parse_policy("kl:0.2:30").unwrap(), &trace),
            Some((30, "kl"))
        );
        let q = parse_policy("patience:20").unwrap();
        let flat: Vec<StepStats> = (0..50).map(|_| stats(1.0, 1.0, 0.0)).collect();
        assert_eq!(drive(&mut *q.clone(), &flat), Some((21, "patience")));
    }

    #[test]
    fn spec_round_trips_through_to_spec() {
        for spec in [
            "entropy:0.5",
            "patience:20:0",
            "patience:20:1.5",
            "kl:0.001:250",
            "fixed:600",
            "none",
            "norm:0.05:3",
            "klslope:0.02:5",
            "any(entropy:0.5,patience:20:0)",
            "all(entropy:0.25,kl:0.001:0)",
            "min(50,entropy:0.25)",
            "ema(0.3,entropy:0.25)",
            "any(ema(0.25,entropy:0.5),min(10,kl:0.001:0),fixed:90)",
            "tokstab:8",
            "tokentropy:0.1",
            "any(entropy:0.5,tokstab:8)",
            "min(20,any(tokentropy:0.05,tokstab:6,kl:0.001:0))",
        ] {
            let p = parse_policy(spec)
                .unwrap_or_else(|| panic!("{spec} must parse"));
            assert_eq!(p.to_spec(), spec, "canonical form of {spec}");
            let q = parse_policy(&p.to_spec()).unwrap();
            assert_eq!(q.to_spec(), p.to_spec(), "round-trip of {spec}");
        }
        // legacy short forms normalize to canonical specs
        assert_eq!(parse_policy("patience:20").unwrap().to_spec(), "patience:20:0");
        assert_eq!(parse_policy("kl:0.001").unwrap().to_spec(), "kl:0.001:0");
        assert_eq!(parse_policy("kl:1e-3:250").unwrap().to_spec(), "kl:0.001:250");
        assert_eq!(parse_policy("norm:0.05").unwrap().to_spec(), "norm:0.05:3");
    }

    #[test]
    fn malformed_specs_rejected() {
        for bad in [
            "",
            "bogus:1",
            "entropy",
            "entropy:x",
            "entropy:0.5:9",
            "any()",
            "any(entropy:0.5",
            "any(entropy:0.5,)",
            "all()",
            "min(entropy:0.5)",
            "min(x,entropy:0.5)",
            "ema(0.3)",
            "nope(entropy:0.5)",
            "any(bogus:1,entropy:0.5)",
            "tokstab",
            "tokstab:0",
            "tokstab:8:2",
            "tokentropy",
            "tokentropy:x",
        ] {
            assert!(parse_policy(bad).is_none(), "{bad:?} must be rejected");
        }
    }

    /// TokenStats over owned lanes, for tests.
    pub(crate) struct TokLanes {
        pub entropy: Vec<f32>,
        pub changed: Vec<f32>,
        pub frozen: Vec<f32>,
    }

    impl TokLanes {
        pub(crate) fn new(l: usize) -> TokLanes {
            TokLanes {
                entropy: vec![1.0; l],
                changed: vec![1.0; l],
                frozen: vec![0.0; l],
            }
        }

        pub(crate) fn view(&self) -> TokenStats<'_> {
            TokenStats {
                entropy: &self.entropy,
                changed: &self.changed,
                frozen: &self.frozen,
            }
        }
    }

    #[test]
    fn tokstab_freezes_after_n_stable_steps() {
        let mut p = TokStab::new(3);
        let mut lanes = TokLanes::new(4);
        lanes.changed = vec![0.0, 0.0, 1.0, 0.0];
        let st = stats(1.0, 1.0, 1.0);
        // step 0 never counts (no previous tokens); then 3 stable steps
        for step in 0..3 {
            assert_eq!(
                p.observe_tokens(step, &st, &lanes.view()),
                Decision::Continue,
                "step {step}"
            );
        }
        let d = p.observe_tokens(3, &st, &lanes.view());
        assert_eq!(
            d.freeze_mask(),
            Some(&[true, true, false, true][..]),
            "positions stable for 3 steps freeze; churning position 2 not"
        );
        // a change resets the run
        let mut q = TokStab::new(2);
        let mut lanes = TokLanes::new(1);
        lanes.changed[0] = 0.0;
        assert!(q.observe_tokens(0, &st, &lanes.view()).freeze_mask().is_none());
        assert!(q.observe_tokens(1, &st, &lanes.view()).freeze_mask().is_none());
        lanes.changed[0] = 1.0; // churn: run back to 0
        assert!(q.observe_tokens(2, &st, &lanes.view()).freeze_mask().is_none());
        lanes.changed[0] = 0.0;
        assert!(q.observe_tokens(3, &st, &lanes.view()).freeze_mask().is_none());
        assert!(q.observe_tokens(4, &st, &lanes.view()).freeze_mask().is_some());
    }

    #[test]
    fn tokstab_skips_frozen_positions_and_is_inert_without_lanes() {
        let mut p = TokStab::new(1);
        let mut lanes = TokLanes::new(2);
        lanes.changed = vec![0.0, 0.0];
        lanes.frozen = vec![1.0, 0.0]; // position 0 already frozen
        let st = stats(1.0, 1.0, 1.0);
        p.observe_tokens(0, &st, &lanes.view());
        let d = p.observe_tokens(1, &st, &lanes.view());
        assert_eq!(d.freeze_mask(), Some(&[false, true][..]));
        // sequence-level observe path: never halts, never freezes
        let mut q = TokStab::new(1);
        for i in 0..50 {
            assert_eq!(q.observe(i, &st), Decision::Continue);
        }
    }

    #[test]
    fn tokentropy_freezes_low_entropy_positions() {
        let mut p = TokEntropy::new(0.5);
        let mut lanes = TokLanes::new(3);
        lanes.entropy = vec![0.1, 2.0, 0.4];
        let st = stats(1.0, 1.0, 1.0);
        let d = p.observe_tokens(0, &st, &lanes.view());
        assert_eq!(d.freeze_mask(), Some(&[true, false, true][..]));
        // frozen positions are not re-frozen
        lanes.frozen = vec![1.0, 0.0, 1.0];
        assert_eq!(
            p.observe_tokens(1, &st, &lanes.view()),
            Decision::Continue
        );
    }

    #[test]
    fn any_combines_halt_and_freeze_with_halt_winning() {
        // freeze-only step: the union of both token legs' masks
        let mut p = Any::new(vec![
            Box::new(TokEntropy::new(0.5)),
            Box::new(TokStab::new(1)),
            Box::new(Entropy::new(0.1)),
        ]);
        let mut lanes = TokLanes::new(3);
        lanes.entropy = vec![0.1, 2.0, 2.0];
        lanes.changed = vec![1.0, 1.0, 0.0];
        let st = stats(1.0, 1.0, 1.0);
        p.observe_tokens(0, &st, &lanes.view());
        let d = p.observe_tokens(1, &st, &lanes.view());
        assert_eq!(d.freeze_mask(), Some(&[true, false, true][..]));
        // a halting leg wins over freezes in the same step
        let low = stats(0.05, 1.0, 1.0);
        let d = p.observe_tokens(2, &low, &lanes.view());
        assert_eq!(d, Decision::Halt { reason: "entropy" });
    }

    #[test]
    fn min_steps_suppresses_freezes_too() {
        let mut p = MinSteps::new(5, Box::new(TokEntropy::new(0.5)));
        let mut low = TokLanes::new(2);
        low.entropy = vec![0.0, 0.0];
        let st = stats(1.0, 1.0, 1.0);
        for step in 0..4 {
            assert_eq!(
                p.observe_tokens(step, &st, &low.view()),
                Decision::Continue,
                "guarded step {step}"
            );
        }
        assert!(p
            .observe_tokens(4, &st, &low.view())
            .freeze_mask()
            .is_some());
    }

    #[test]
    fn sequence_policies_identical_on_both_observe_paths() {
        // the default observe_tokens must not change sequence-level
        // behaviour: drive the same policy over both call paths
        let trace: Vec<StepStats> =
            (0..60).map(|i| stats(2.0 - 0.04 * i as f32, 0.1, 1.0)).collect();
        let lanes = TokLanes::new(8);
        for spec in ["entropy:0.5", "patience:5:0", "kl:0.15:10", "fixed:30"] {
            let via_observe = {
                let mut p = parse_policy(spec).unwrap();
                p.reset();
                trace
                    .iter()
                    .enumerate()
                    .find_map(|(i, st)| p.observe(i, st).halted().then_some(i))
            };
            let via_tokens = {
                let mut p = parse_policy(spec).unwrap();
                p.reset();
                trace.iter().enumerate().find_map(|(i, st)| {
                    p.observe_tokens(i, st, &lanes.view())
                        .halted()
                        .then_some(i)
                })
            };
            assert_eq!(via_observe, via_tokens, "{spec}");
        }
    }

    #[test]
    fn registry_accepts_custom_primitives() {
        // an out-of-tree policy: halt when switches exceed a threshold
        #[derive(Clone, Copy)]
        struct Churn {
            limit: f32,
        }
        impl HaltPolicy for Churn {
            fn observe(&mut self, _step: usize, st: &StepStats) -> Decision {
                if st.switches >= self.limit {
                    Decision::Halt { reason: "churn" }
                } else {
                    Decision::Continue
                }
            }
            fn name(&self) -> &'static str {
                "churn"
            }
            fn to_spec(&self) -> String {
                format!("churn:{}", self.limit)
            }
            fn clone_box(&self) -> BoxedPolicy {
                Box::new(*self)
            }
        }
        let mut reg = Registry::builtin();
        reg.register("churn", |args| {
            if args.len() != 1 {
                return None;
            }
            Some(Box::new(Churn {
                limit: args[0].parse().ok()?,
            }))
        });
        let p = reg.parse("any(churn:5,fixed:9)").unwrap();
        let trace = vec![stats(1.0, 1.0, 7.0); 4];
        assert_eq!(drive(&mut *p.clone(), &trace), Some((1, "churn")));
        // custom names still unknown to the default registry
        assert!(parse_policy("churn:5").is_none());
    }
}
