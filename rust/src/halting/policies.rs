//! Primitive halting policies: the paper's Algorithms 1-3, the fixed-step
//! baseline, and two signals the closed enum API could not express
//! (norm stabilisation, relative-KL-slope).

use super::{BoxedPolicy, Decision, HaltPolicy, StepStats, TokenStats};

/// Algorithm 1: halt when the entropy of p(x0|x_t, t) drops to
/// `threshold`.
#[derive(Clone, Copy, Debug)]
pub struct Entropy {
    pub threshold: f32,
}

impl Entropy {
    pub fn new(threshold: f32) -> Entropy {
        Entropy { threshold }
    }
}

impl HaltPolicy for Entropy {
    fn observe(&mut self, _step: usize, stats: &StepStats) -> Decision {
        if stats.entropy <= self.threshold {
            Decision::Halt { reason: "entropy" }
        } else {
            Decision::Continue
        }
    }

    fn name(&self) -> &'static str {
        "entropy"
    }

    fn to_spec(&self) -> String {
        format!("entropy:{}", self.threshold)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Algorithm 2: halt after `patience` consecutive steps whose argmax
/// tokens changed at most `tolerance` positions.  Step 0 is ignored (no
/// previous tokens to compare against).
#[derive(Clone, Copy, Debug)]
pub struct Patience {
    pub patience: usize,
    pub tolerance: f32,
    run: usize,
}

impl Patience {
    pub fn new(patience: usize, tolerance: f32) -> Patience {
        Patience {
            patience,
            tolerance,
            run: 0,
        }
    }
}

impl HaltPolicy for Patience {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        if step > 0 && stats.switches <= self.tolerance {
            self.run += 1;
        } else {
            self.run = 0;
        }
        if self.run >= self.patience {
            Decision::Halt { reason: "patience" }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        self.run = 0;
    }

    fn name(&self) -> &'static str {
        "patience"
    }

    fn to_spec(&self) -> String {
        format!("patience:{}:{}", self.patience, self.tolerance)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Algorithm 3: halt when KL(p_t || p_{t-1}) <= `threshold`, after at
/// least `min_steps` steps (paper: min_steps ~ 0.25 N_max).  Step 0 never
/// fires (no previous distribution).
#[derive(Clone, Copy, Debug)]
pub struct Kl {
    pub threshold: f32,
    pub min_steps: usize,
}

impl Kl {
    pub fn new(threshold: f32, min_steps: usize) -> Kl {
        Kl {
            threshold,
            min_steps,
        }
    }
}

impl HaltPolicy for Kl {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        if step > 0 && step + 1 >= self.min_steps && stats.kl <= self.threshold
        {
            Decision::Halt { reason: "kl" }
        } else {
            Decision::Continue
        }
    }

    fn name(&self) -> &'static str {
        "kl"
    }

    fn to_spec(&self) -> String {
        format!("kl:{}:{}", self.threshold, self.min_steps)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Fixed-step baseline: halt unconditionally once `step` steps ran.  A
/// zero-step budget resolves in `preflight`, before any device step.
#[derive(Clone, Copy, Debug)]
pub struct Fixed {
    pub step: usize,
}

impl Fixed {
    pub fn new(step: usize) -> Fixed {
        Fixed { step }
    }
}

impl HaltPolicy for Fixed {
    fn observe(&mut self, step: usize, _stats: &StepStats) -> Decision {
        if step + 1 >= self.step {
            Decision::Halt { reason: "fixed" }
        } else {
            Decision::Continue
        }
    }

    fn preflight(&self) -> Decision {
        if self.step == 0 {
            Decision::Halt { reason: "fixed" }
        } else {
            Decision::Continue
        }
    }

    fn name(&self) -> &'static str {
        "fixed"
    }

    fn to_spec(&self) -> String {
        format!("fixed:{}", self.step)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Never halt (full-schedule baseline).
#[derive(Clone, Copy, Debug)]
pub struct NoHalt;

impl HaltPolicy for NoHalt {
    fn observe(&mut self, _step: usize, _stats: &StepStats) -> Decision {
        Decision::Continue
    }

    fn name(&self) -> &'static str {
        "none"
    }

    fn to_spec(&self) -> String {
        "none".to_string()
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Norm stabilisation: ||x|| relaxes toward ||x0_hat|| as denoising
/// settles (paper Fig 2).  Halts after `patience` consecutive steps with
/// |norm_x - norm_x0| <= threshold * norm_x0.
#[derive(Clone, Copy, Debug)]
pub struct NormStable {
    pub threshold: f32,
    pub patience: usize,
    run: usize,
}

impl NormStable {
    pub fn new(threshold: f32, patience: usize) -> NormStable {
        NormStable {
            threshold,
            patience: patience.max(1),
            run: 0,
        }
    }
}

impl HaltPolicy for NormStable {
    fn observe(&mut self, _step: usize, stats: &StepStats) -> Decision {
        let gap = (stats.norm_x - stats.norm_x0).abs();
        if gap <= self.threshold * stats.norm_x0.max(1e-6) {
            self.run += 1;
        } else {
            self.run = 0;
        }
        if self.run >= self.patience {
            Decision::Halt { reason: "norm" }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        self.run = 0;
    }

    fn name(&self) -> &'static str {
        "norm"
    }

    fn to_spec(&self) -> String {
        format!("norm:{}:{}", self.threshold, self.patience)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Relative-KL-slope: halt when the per-step KL stops shrinking — the
/// relative decrease (kl_prev - kl) / kl_prev stays at or below `flat`
/// for `window` consecutive steps.  Scale-free alternative to an
/// absolute KL threshold (robust across schedule lengths).
#[derive(Clone, Copy, Debug)]
pub struct KlSlope {
    pub flat: f32,
    pub window: usize,
    prev: Option<f32>,
    run: usize,
}

impl KlSlope {
    pub fn new(flat: f32, window: usize) -> KlSlope {
        KlSlope {
            flat,
            window: window.max(1),
            prev: None,
            run: 0,
        }
    }
}

impl HaltPolicy for KlSlope {
    fn observe(&mut self, _step: usize, stats: &StepStats) -> Decision {
        let rel_decrease = match self.prev {
            Some(p) if p > 0.0 => (p - stats.kl) / p,
            Some(_) => 0.0, // KL already at zero: flat
            None => f32::INFINITY,
        };
        self.prev = Some(stats.kl);
        if rel_decrease <= self.flat {
            self.run += 1;
        } else {
            self.run = 0;
        }
        if self.run >= self.window {
            Decision::Halt { reason: "klslope" }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        self.prev = None;
        self.run = 0;
    }

    fn name(&self) -> &'static str {
        "klslope"
    }

    fn to_spec(&self) -> String {
        format!("klslope:{}:{}", self.flat, self.window)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}

/// Token-level argmax stability: freeze a position once its argmax token
/// has been unchanged for `n` consecutive steps ("Just on Time"-style
/// per-token early stopping).  Run lengths accumulate host-side from the
/// per-position argmax-changed lane; already-frozen positions are
/// skipped.  Without token lanes (format-2 artifacts, or a kernel that
/// opts out of token halting) this policy is inert — it never halts a
/// sequence by itself.
#[derive(Clone, Debug)]
pub struct TokStab {
    pub n: u32,
    runs: Vec<u32>,
}

impl TokStab {
    pub fn new(n: u32) -> TokStab {
        TokStab {
            n: n.max(1),
            runs: Vec::new(),
        }
    }
}

impl HaltPolicy for TokStab {
    fn observe(&mut self, _step: usize, _stats: &StepStats) -> Decision {
        Decision::Continue
    }

    fn observe_tokens(
        &mut self,
        step: usize,
        _stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        let l = tok.changed.len();
        self.runs.resize(l, 0);
        let mut mask = vec![false; l];
        let mut any = false;
        for p in 0..l {
            if tok.frozen[p] > 0.5 {
                continue;
            }
            // step 0 has no previous tokens to compare against
            if step > 0 && tok.changed[p] <= 0.5 {
                self.runs[p] += 1;
            } else {
                self.runs[p] = 0;
            }
            if self.runs[p] >= self.n {
                mask[p] = true;
                any = true;
            }
        }
        if any {
            Decision::Freeze { mask }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        self.runs.clear();
    }

    fn name(&self) -> &'static str {
        "tokstab"
    }

    fn to_spec(&self) -> String {
        format!("tokstab:{}", self.n)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(self.clone())
    }
}

/// Token-level entropy: freeze a position once its own entropy H(p_p)
/// drops to `threshold` (the per-position form of Algorithm 1).  Inert
/// without token lanes, like [`TokStab`].
#[derive(Clone, Copy, Debug)]
pub struct TokEntropy {
    pub threshold: f32,
}

impl TokEntropy {
    pub fn new(threshold: f32) -> TokEntropy {
        TokEntropy { threshold }
    }
}

impl HaltPolicy for TokEntropy {
    fn observe(&mut self, _step: usize, _stats: &StepStats) -> Decision {
        Decision::Continue
    }

    fn observe_tokens(
        &mut self,
        _step: usize,
        _stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        let mut mask = vec![false; tok.entropy.len()];
        let mut any = false;
        for (p, m) in mask.iter_mut().enumerate() {
            if tok.frozen[p] <= 0.5 && tok.entropy[p] <= self.threshold {
                *m = true;
                any = true;
            }
        }
        if any {
            Decision::Freeze { mask }
        } else {
            Decision::Continue
        }
    }

    fn name(&self) -> &'static str {
        "tokentropy"
    }

    fn to_spec(&self) -> String {
        format!("tokentropy:{}", self.threshold)
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(*self)
    }
}
