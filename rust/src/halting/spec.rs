//! Spec DSL for halting policies — the single string form used by the
//! CLI (`--criterion`), the JSON wire protocol, and experiment labels.
//!
//! Grammar (whitespace-insensitive at argument boundaries):
//!
//! ```text
//! policy     := combinator | primitive
//! combinator := "any" "(" policy {"," policy} ")"
//!             | "all" "(" policy {"," policy} ")"
//!             | "min" "(" INT "," policy ")"
//!             | "ema" "(" FLOAT "," policy ")"
//! primitive  := NAME {":" NUMBER}
//! ```
//!
//! Built-in primitives: `entropy:T`, `patience:P[:TOL]`, `kl:T[:MIN]`,
//! `fixed:N`, `none`, `norm:T[:P]`, `klslope:F[:W]`, plus the
//! token-level primitives `tokstab:N` (freeze a position once its argmax
//! is unchanged N steps) and `tokentropy:T` (freeze when a position's
//! own entropy drops to T).  The bracketed arguments default to the
//! legacy enum's values, so every pre-DSL spec string (`entropy:0.5`,
//! `patience:20`, `kl:1e-3:250`, `fixed:600`, `none`) parses to an
//! equivalent policy.  `HaltPolicy::to_spec` emits the canonical
//! fully-argumented form and round-trips through [`parse_policy`].
//! Token-level primitives compose like any other —
//! `any(entropy:0.5,tokstab:8)` freezes settled positions while the
//! entropy criterion can still halt the whole sequence.

use super::combinators::{All, Any, Ema, MinSteps};
use super::policies::{
    Entropy, Fixed, Kl, KlSlope, NoHalt, NormStable, Patience, TokEntropy,
    TokStab,
};
use super::BoxedPolicy;

/// Constructor for a primitive policy from its `:`-separated arguments.
pub type PrimitiveCtor = fn(&[&str]) -> Option<BoxedPolicy>;

/// Open registry of primitive policies.  `Registry::builtin()` knows the
/// in-tree primitives; `register` adds out-of-tree ones (combinators are
/// part of the grammar and compose over every registered primitive).
pub struct Registry {
    ctors: Vec<(&'static str, PrimitiveCtor)>,
}

impl Registry {
    /// Registry with all in-tree primitives.
    pub fn builtin() -> Registry {
        let mut r = Registry { ctors: Vec::new() };
        r.register("none", ctor_none);
        r.register("entropy", ctor_entropy);
        r.register("patience", ctor_patience);
        r.register("kl", ctor_kl);
        r.register("fixed", ctor_fixed);
        r.register("norm", ctor_norm);
        r.register("klslope", ctor_klslope);
        r.register("tokstab", ctor_tokstab);
        r.register("tokentropy", ctor_tokentropy);
        r
    }

    /// Add (or shadow) a primitive; later registrations win.
    pub fn register(&mut self, name: &'static str, ctor: PrimitiveCtor) {
        self.ctors.retain(|(n, _)| *n != name);
        self.ctors.push((name, ctor));
    }

    /// Parse a spec string into a policy; `None` on any malformed input.
    pub fn parse(&self, s: &str) -> Option<BoxedPolicy> {
        let s = s.trim();
        if s.is_empty() {
            return None;
        }
        // combinator form: name(arg,...)
        if let Some(open) = s.find('(') {
            if !s.ends_with(')') {
                return None;
            }
            let name = s[..open].trim();
            let args = split_top_level(&s[open + 1..s.len() - 1])?;
            return match name {
                "any" => Some(Box::new(Any::new(self.parse_all(&args)?))),
                "all" => Some(Box::new(All::new(self.parse_all(&args)?))),
                "min" => {
                    if args.len() != 2 {
                        return None;
                    }
                    let min: usize = args[0].trim().parse().ok()?;
                    Some(Box::new(MinSteps::new(min, self.parse(args[1])?)))
                }
                "ema" => {
                    if args.len() != 2 {
                        return None;
                    }
                    let alpha: f32 = args[0].trim().parse().ok()?;
                    if alpha.is_nan() || alpha <= 0.0 || alpha > 1.0 {
                        return None;
                    }
                    Some(Box::new(Ema::new(alpha, self.parse(args[1])?)))
                }
                _ => None,
            };
        }
        // primitive form: name[:arg]*
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        let (name, args) = (parts[0], &parts[1..]);
        self.ctors
            .iter()
            .find(|(n, _)| *n == name)
            .and_then(|(_, ctor)| ctor(args))
    }

    fn parse_all(&self, args: &[&str]) -> Option<Vec<BoxedPolicy>> {
        if args.is_empty() {
            return None;
        }
        args.iter().map(|a| self.parse(a)).collect()
    }
}

/// Parse with the built-in registry (the common path: CLI and wire).
pub fn parse_policy(s: &str) -> Option<BoxedPolicy> {
    Registry::builtin().parse(s)
}

/// Split on commas at parenthesis depth 0; rejects unbalanced parens and
/// empty arguments.
fn split_top_level(s: &str) -> Option<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth = depth.checked_sub(1)?,
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if depth != 0 {
        return None;
    }
    out.push(&s[start..]);
    if out.iter().any(|a| a.trim().is_empty()) {
        return None;
    }
    Some(out)
}

fn ctor_none(args: &[&str]) -> Option<BoxedPolicy> {
    args.is_empty().then(|| Box::new(NoHalt) as BoxedPolicy)
}

fn ctor_entropy(args: &[&str]) -> Option<BoxedPolicy> {
    if args.len() != 1 {
        return None;
    }
    Some(Box::new(Entropy::new(args[0].parse().ok()?)))
}

fn ctor_patience(args: &[&str]) -> Option<BoxedPolicy> {
    if args.is_empty() || args.len() > 2 {
        return None;
    }
    let patience: usize = args[0].parse().ok()?;
    let tolerance: f32 = match args.get(1) {
        Some(t) => t.parse().ok()?,
        None => 0.0,
    };
    Some(Box::new(Patience::new(patience, tolerance)))
}

fn ctor_kl(args: &[&str]) -> Option<BoxedPolicy> {
    if args.is_empty() || args.len() > 2 {
        return None;
    }
    let threshold: f32 = args[0].parse().ok()?;
    let min_steps: usize = match args.get(1) {
        Some(m) => m.parse().ok()?,
        None => 0,
    };
    Some(Box::new(Kl::new(threshold, min_steps)))
}

fn ctor_fixed(args: &[&str]) -> Option<BoxedPolicy> {
    if args.len() != 1 {
        return None;
    }
    // fixed:0 is deliberately accepted: a zero-step budget resolves in
    // preflight (see `Fixed::preflight`), not after one executed step
    Some(Box::new(Fixed::new(args[0].parse().ok()?)))
}

fn ctor_norm(args: &[&str]) -> Option<BoxedPolicy> {
    if args.is_empty() || args.len() > 2 {
        return None;
    }
    let threshold: f32 = args[0].parse().ok()?;
    let patience: usize = match args.get(1) {
        Some(p) => p.parse().ok()?,
        None => 3,
    };
    Some(Box::new(NormStable::new(threshold, patience)))
}

fn ctor_klslope(args: &[&str]) -> Option<BoxedPolicy> {
    if args.is_empty() || args.len() > 2 {
        return None;
    }
    let flat: f32 = args[0].parse().ok()?;
    let window: usize = match args.get(1) {
        Some(w) => w.parse().ok()?,
        None => 5,
    };
    Some(Box::new(KlSlope::new(flat, window)))
}

fn ctor_tokstab(args: &[&str]) -> Option<BoxedPolicy> {
    if args.len() != 1 {
        return None;
    }
    let n: u32 = args[0].parse().ok()?;
    if n == 0 {
        return None;
    }
    Some(Box::new(TokStab::new(n)))
}

fn ctor_tokentropy(args: &[&str]) -> Option<BoxedPolicy> {
    if args.len() != 1 {
        return None;
    }
    Some(Box::new(TokEntropy::new(args[0].parse().ok()?)))
}
