//! Policy combinators: disjunction, conjunction, a minimum-step guard,
//! and an EMA smoothing wrapper.  Combinators propagate the *reason* of
//! the primitive that fired, so per-reason metrics stay meaningful under
//! composition.

use super::{BoxedPolicy, Decision, HaltPolicy, StepStats, TokenStats};

fn join_specs(policies: &[BoxedPolicy]) -> String {
    policies
        .iter()
        .map(|p| p.to_spec())
        .collect::<Vec<_>>()
        .join(",")
}

/// Fold a leg's freeze mask into the union accumulator.
fn union_freeze(acc: &mut Option<Vec<bool>>, mask: &[bool]) {
    match acc {
        None => *acc = Some(mask.to_vec()),
        Some(u) => {
            if u.len() < mask.len() {
                u.resize(mask.len(), false);
            }
            for (a, &m) in u.iter_mut().zip(mask) {
                *a |= m;
            }
        }
    }
}

/// Halt as soon as any inner policy fires; the reason is the firing
/// policy's reason.
#[derive(Clone)]
pub struct Any {
    policies: Vec<BoxedPolicy>,
}

impl Any {
    pub fn new(policies: Vec<BoxedPolicy>) -> Any {
        Any { policies }
    }
}

impl HaltPolicy for Any {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        // feed every leg even after one fires: a wrapper (MinSteps/Ema)
        // may suppress this halt, and later legs' state must keep
        // accruing as if they had seen the full trace
        let mut first = Decision::Continue;
        for p in &mut self.policies {
            let d = p.observe(step, stats);
            if !first.halted() && d.halted() {
                first = d;
            }
        }
        first
    }

    fn observe_tokens(
        &mut self,
        step: usize,
        stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        // halt wins over freeze; freeze masks from different legs union
        let mut halt = Decision::Continue;
        let mut freeze: Option<Vec<bool>> = None;
        for p in &mut self.policies {
            let d = p.observe_tokens(step, stats, tok);
            if let Some(mask) = d.freeze_mask() {
                union_freeze(&mut freeze, mask);
            } else if !halt.halted() && d.halted() {
                halt = d;
            }
        }
        if halt.halted() {
            halt
        } else if let Some(mask) = freeze {
            Decision::Freeze { mask }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        for p in &mut self.policies {
            p.reset();
        }
    }

    fn preflight(&self) -> Decision {
        for p in &self.policies {
            let d = p.preflight();
            if d.halted() {
                return d;
            }
        }
        Decision::Continue
    }

    fn name(&self) -> &'static str {
        "any"
    }

    fn to_spec(&self) -> String {
        format!("any({})", join_specs(&self.policies))
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(self.clone())
    }
}

/// Halt once every inner policy has fired at least once.  Each inner
/// fire is latched (the signal does not need to stay low); a latched
/// policy stops being fed.  The reason is the policy that completed the
/// conjunction.
#[derive(Clone)]
pub struct All {
    policies: Vec<BoxedPolicy>,
    fired: Vec<bool>,
    /// reason of the leg that completed the conjunction, latched so a
    /// suppressing wrapper (MinSteps) still sees the primitive reason
    /// on later steps
    reason: Option<&'static str>,
}

impl All {
    pub fn new(policies: Vec<BoxedPolicy>) -> All {
        let n = policies.len();
        All {
            policies,
            fired: vec![false; n],
            reason: None,
        }
    }
}

impl HaltPolicy for All {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        for (i, p) in self.policies.iter_mut().enumerate() {
            if self.fired[i] {
                continue;
            }
            if let Decision::Halt { reason } = p.observe(step, stats) {
                self.fired[i] = true;
                self.reason = Some(reason);
            }
        }
        if !self.fired.is_empty() && self.fired.iter().all(|&f| f) {
            Decision::Halt {
                reason: self.reason.unwrap_or("all"),
            }
        } else {
            Decision::Continue
        }
    }

    fn observe_tokens(
        &mut self,
        step: usize,
        stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        // halts latch towards the conjunction as in `observe`; freezes
        // are *actions*, not votes — they apply immediately and never
        // latch a leg
        let mut freeze: Option<Vec<bool>> = None;
        for (i, p) in self.policies.iter_mut().enumerate() {
            if self.fired[i] {
                continue;
            }
            match p.observe_tokens(step, stats, tok) {
                Decision::Halt { reason } => {
                    self.fired[i] = true;
                    self.reason = Some(reason);
                }
                Decision::Freeze { mask } => union_freeze(&mut freeze, &mask),
                Decision::Continue => {}
            }
        }
        if !self.fired.is_empty() && self.fired.iter().all(|&f| f) {
            Decision::Halt {
                reason: self.reason.unwrap_or("all"),
            }
        } else if let Some(mask) = freeze {
            Decision::Freeze { mask }
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        for p in &mut self.policies {
            p.reset();
        }
        self.fired.iter_mut().for_each(|f| *f = false);
        self.reason = None;
    }

    fn preflight(&self) -> Decision {
        let mut last = Decision::Continue;
        for p in &self.policies {
            let d = p.preflight();
            if !d.halted() {
                return Decision::Continue;
            }
            last = d;
        }
        if self.policies.is_empty() {
            Decision::Continue
        } else {
            last
        }
    }

    fn name(&self) -> &'static str {
        "all"
    }

    fn to_spec(&self) -> String {
        format!("all({})", join_specs(&self.policies))
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(self.clone())
    }
}

/// Guard: suppress inner halts until `min` steps have completed (the
/// inner policy still observes every step, so its state accrues).
#[derive(Clone)]
pub struct MinSteps {
    min: usize,
    inner: BoxedPolicy,
}

impl MinSteps {
    pub fn new(min: usize, inner: BoxedPolicy) -> MinSteps {
        MinSteps { min, inner }
    }
}

impl HaltPolicy for MinSteps {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        let d = self.inner.observe(step, stats);
        if step + 1 >= self.min {
            d
        } else {
            Decision::Continue
        }
    }

    fn observe_tokens(
        &mut self,
        step: usize,
        stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        // the guard suppresses freezes as well as halts: no position may
        // be pinned before `min` steps have run
        let d = self.inner.observe_tokens(step, stats, tok);
        if step + 1 >= self.min {
            d
        } else {
            Decision::Continue
        }
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn preflight(&self) -> Decision {
        if self.min == 0 {
            self.inner.preflight()
        } else {
            Decision::Continue
        }
    }

    fn name(&self) -> &'static str {
        "min"
    }

    fn to_spec(&self) -> String {
        format!("min({},{})", self.min, self.inner.to_spec())
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(self.clone())
    }
}

/// Smoothing wrapper: exponential moving average over every raw signal
/// before the inner policy sees it (`alpha` = weight of the newest
/// sample; the first sample seeds the average).  Useful to keep noisy
/// entropy/KL traces from triggering a threshold on a single dip.
#[derive(Clone)]
pub struct Ema {
    alpha: f32,
    inner: BoxedPolicy,
    state: Option<StepStats>,
}

impl Ema {
    pub fn new(alpha: f32, inner: BoxedPolicy) -> Ema {
        Ema {
            alpha: alpha.clamp(1e-3, 1.0),
            inner,
            state: None,
        }
    }

    fn smooth(&mut self, stats: &StepStats) -> StepStats {
        let sm = match self.state {
            None => *stats,
            Some(prev) => {
                let a = self.alpha;
                let b = 1.0 - a;
                StepStats {
                    entropy: a * stats.entropy + b * prev.entropy,
                    kl: a * stats.kl + b * prev.kl,
                    switches: a * stats.switches + b * prev.switches,
                    norm_x0: a * stats.norm_x0 + b * prev.norm_x0,
                    norm_x: a * stats.norm_x + b * prev.norm_x,
                }
            }
        };
        self.state = Some(sm);
        sm
    }
}

impl HaltPolicy for Ema {
    fn observe(&mut self, step: usize, stats: &StepStats) -> Decision {
        let sm = self.smooth(stats);
        self.inner.observe(step, &sm)
    }

    fn observe_tokens(
        &mut self,
        step: usize,
        stats: &StepStats,
        tok: &TokenStats<'_>,
    ) -> Decision {
        // scalar signals are smoothed; token lanes pass through raw (the
        // argmax-changed lane is a discrete flag — averaging it would
        // change the tokstab run semantics)
        let sm = self.smooth(stats);
        self.inner.observe_tokens(step, &sm, tok)
    }

    fn reset(&mut self) {
        self.state = None;
        self.inner.reset();
    }

    fn preflight(&self) -> Decision {
        self.inner.preflight()
    }

    fn name(&self) -> &'static str {
        "ema"
    }

    fn to_spec(&self) -> String {
        format!("ema({},{})", self.alpha, self.inner.to_spec())
    }

    fn clone_box(&self) -> BoxedPolicy {
        Box::new(self.clone())
    }
}
