//! The check catalogue: five architectural invariants of the serving
//! stack, each a pure function over the lexed tree.
//!
//! | check              | scope                         | invariant |
//! |--------------------|-------------------------------|-----------|
//! | `panic-freedom`    | serving-path modules          | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` outside test code |
//! | `lock-poison`      | serving-path modules          | no `.lock().unwrap()` — use `util::sync::lock_or_recover` |
//! | `family-seal`      | whole tree minus the seam     | no `match` over `Family`/`FamilyId` outside `sampler/kernel.rs` + `sampler/registry.rs` |
//! | `metrics-registry` | snapshot emitters             | every emitted metrics key/prefix is declared in `coordinator::metrics::keys`; `bench_schema.txt` ⊆ registry |
//! | `wire-doc-drift`   | `coordinator/envelope.rs`     | every constructed frame field name appears in API.md |
//! | `unsafe-hygiene`   | whole tree                    | every `unsafe` is immediately preceded by a `// SAFETY:` comment |
//! | `lock-order`       | whole tree                    | no pair of locks is acquired (via `lock_or_recover`) in both nesting orders — inverted nesting can deadlock; one-directional nesting is legal |
//!
//! Matches on `#[cfg(test)]` lines are skipped; a well-formed
//! `// lint:allow(<check>): <reason>` on the line above (or the line
//! of) a match suppresses it.  See `analysis::source` for the grammar.

use std::collections::{BTreeMap, BTreeSet};

use super::report::Violation;
use super::scan::{
    brace_end, contains_word, eat, eat_ident, eat_key, find_all, find_words,
    skip_ws,
};
use super::source::SourceFile;

/// Serving-path scope for panic-freedom / lock-poison: the modules a
/// wire request's execution can traverse.
const SERVING_PREFIXES: &[&str] = &["coordinator/", "predictor/", "halting/"];
const SERVING_FILES: &[&str] =
    &["sampler/session.rs", "runtime/artifact_cache.rs"];

/// The only two files allowed to match on the family enum: the kernel
/// trait's dispatch seam.
const FAMILY_SEAL_EXEMPT: &[&str] =
    &["sampler/kernel.rs", "sampler/registry.rs"];

/// The files that assemble the metrics snapshot.
const METRICS_EMITTERS: &[&str] = &[
    "coordinator/metrics/mod.rs",
    "coordinator/engine.rs",
    "predictor/estimator.rs",
];

const WIRE_FILE: &str = "coordinator/envelope.rs";

/// Cross-file inputs the tree-level checks need.
pub struct Context {
    /// raw API.md text (wire-doc-drift)
    pub api_md: String,
    /// raw `coordinator/metrics/keys.rs` source (metrics-registry);
    /// parsed textually so the analyzer never links the crate it lints
    pub keys_src: String,
    /// raw `scripts/bench_schema.txt`, when present
    pub bench_schema: Option<String>,
}

pub fn serving_path(rel: &str) -> bool {
    SERVING_PREFIXES.iter().any(|p| rel.starts_with(p))
        || SERVING_FILES.contains(&rel)
}

/// Run every check over the tree.  Violations come back sorted by
/// (check, file, line).
pub fn run_all(files: &[SourceFile], ctx: &Context) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if serving_path(&f.rel) {
            check_panic_freedom(f, &mut out);
        }
        if !FAMILY_SEAL_EXEMPT.contains(&f.rel.as_str()) {
            check_family_seal(f, &mut out);
        }
        check_unsafe_hygiene(f, &mut out);
    }
    check_metrics_registry(files, ctx, &mut out);
    check_wire_doc_drift(files, ctx, &mut out);
    check_lock_order(files, &mut out);
    out.sort_by(|a, b| {
        (a.check, &a.file, a.line).cmp(&(b.check, &b.file, b.line))
    });
    out
}

fn emit(
    out: &mut Vec<Violation>,
    f: &SourceFile,
    check: &'static str,
    pos: usize,
    msg: String,
) {
    let line = f.line_at(pos);
    if f.test_lines.contains(&line) || f.suppressed(check, line) {
        return;
    }
    out.push(Violation { check, file: f.rel.clone(), line, msg });
}

// ---------------------------------------------------------------- panic

/// `.lock().unwrap()` spans (for lock-poison), so the generic
/// `.unwrap()` scan can skip them — one hazard, one finding.
fn lock_unwrap_spans(code: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for p in find_all(code, b".lock()") {
        let i = skip_ws(code, p + b".lock()".len());
        let Some(i) = eat(code, i, b".") else { continue };
        let i = skip_ws(code, i);
        if let Some(end) = eat(code, i, b"unwrap()") {
            spans.push((p, end));
        }
    }
    spans
}

fn check_panic_freedom(f: &SourceFile, out: &mut Vec<Violation>) {
    let code = &f.lexed.code;
    let lock_spans = lock_unwrap_spans(code);
    for &(p, _) in &lock_spans {
        emit(
            out,
            f,
            "lock-poison",
            p,
            ".lock().unwrap() can poison-cascade a panicked holder; \
             use util::sync::lock_or_recover"
                .to_string(),
        );
    }
    for mac in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        for p in find_words(code, mac.as_bytes()) {
            emit(
                out,
                f,
                "panic-freedom",
                p,
                format!("`{mac}` in a serving-path module"),
            );
        }
    }
    for p in find_all(code, b".unwrap()") {
        if lock_spans.iter().any(|&(a, b)| (a..b).contains(&p)) {
            continue;
        }
        emit(
            out,
            f,
            "panic-freedom",
            p,
            "`.unwrap()` in a serving-path module".to_string(),
        );
    }
    for p in find_all(code, b".expect") {
        // `.expect  (` — method call with optional whitespace; skips
        // identifiers like `.expected` via the ident check
        let after = p + b".expect".len();
        if after < code.len() && super::scan::is_ident(code[after]) {
            continue;
        }
        if code.get(skip_ws(code, after)) == Some(&b'(') {
            emit(
                out,
                f,
                "panic-freedom",
                p,
                "`.expect(..)` in a serving-path module".to_string(),
            );
        }
    }
}

// --------------------------------------------------------- family-seal

/// One violation per `match` expression (reported at the `match`
/// keyword) when either its scrutinee names `Family`/`FamilyId` or its
/// body contains a `Family::X =>` / `Family::X |` arm pattern.  Arm
/// hits attribute to the *innermost* enclosing match, so an outer
/// match over some other enum is not blamed for a nested family match.
fn check_family_seal(f: &SourceFile, out: &mut Vec<Violation>) {
    let code = &f.lexed.code;
    // (match_start, body_open, body_end) for every match expression
    let mut spans = Vec::new();
    for p in find_words(code, b"match") {
        let Some(open) =
            super::lexer::find_bytes(code, b"{", p + b"match".len())
        else {
            continue;
        };
        spans.push((p, open, brace_end(code, open)));
    }
    let mut flagged = BTreeSet::new();
    for &(start, open, _) in &spans {
        let scrut = &code[start..open];
        if contains_word(scrut, b"Family") || contains_word(scrut, b"FamilyId")
        {
            flagged.insert(start);
        }
    }
    for p in family_arm_hits(code) {
        let innermost = spans
            .iter()
            .filter(|&&(_, open, end)| open < p && p < end)
            .max_by_key(|&&(_, open, _)| open);
        if let Some(&(start, _, _)) = innermost {
            flagged.insert(start);
        }
    }
    for start in flagged {
        emit(
            out,
            f,
            "family-seal",
            start,
            "`match` over Family outside the kernel seam \
             (sampler/kernel.rs + sampler/registry.rs)"
                .to_string(),
        );
    }
}

/// Positions of `Family::X =>` / `FamilyId::X |` arm patterns.
fn family_arm_hits(code: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    for p in find_words(code, b"Family") {
        let mut i = p + b"Family".len();
        if let Some(j) = eat(code, i, b"Id") {
            i = j;
        }
        let Some(i) = eat(code, i, b"::") else { continue };
        let Some(i) = eat_ident(code, i) else { continue };
        let i = skip_ws(code, i);
        if eat(code, i, b"=>").is_some() || eat(code, i, b"|").is_some() {
            out.push(p);
        }
    }
    out
}

// ---------------------------------------------------- metrics-registry

/// String keys constructed in an emitter file's `text` view:
/// `("key", ...)` pairs, `.insert("key"`, and `format!("prefix{`
/// dynamic lanes (returned separately).  A key is reported once per
/// line even when both the pair and the insert pattern match it.
fn emitted_keys(f: &SourceFile) -> (Vec<(usize, String)>, Vec<(usize, String)>)
{
    let text = &f.lexed.text;
    let mut keys = Vec::new();
    for p in find_all(text, b"(") {
        let i = skip_ws(text, p + 1);
        let Some(i) = eat(text, i, b"\"") else { continue };
        let Some((key, i)) = eat_key(text, i) else { continue };
        let Some(i) = eat(text, i, b"\"") else { continue };
        if text.get(skip_ws(text, i)) == Some(&b',') {
            keys.push((p, key));
        }
    }
    for p in find_all(text, b".insert(") {
        let i = skip_ws(text, p + b".insert(".len());
        let Some(i) = eat(text, i, b"\"") else { continue };
        let Some((key, i)) = eat_key(text, i) else { continue };
        if eat(text, i, b"\"").is_some() {
            keys.push((p, key));
        }
    }
    let mut prefixes = Vec::new();
    for p in find_all(text, b"format!(") {
        let i = skip_ws(text, p + b"format!(".len());
        let Some(i) = eat(text, i, b"\"") else { continue };
        let Some((key, i)) = eat_key(text, i) else { continue };
        if text.get(i) == Some(&b'{') {
            prefixes.push((p, key));
        }
    }
    keys.sort_by_key(|&(p, _)| p);
    let mut seen = BTreeSet::new();
    keys.retain(|(p, key)| seen.insert((f.line_at(*p), key.clone())));
    (keys, prefixes)
}

/// Textual parse of a `const NAME: ... = &[ "a", "b", ... ];` array in
/// `keys.rs` — the analyzer reads the registry as source, it does not
/// link it.
fn declared_array(keys_src: &str, name: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let Some(at) = keys_src.find(&format!("const {name}")) else {
        return out;
    };
    let rest = &keys_src[at..];
    let Some(eq) = rest.find('=') else { return out };
    let Some(open) = rest[eq..].find('[') else { return out };
    let body_start = eq + open + 1;
    let Some(close) = rest[body_start..].find("];") else { return out };
    let body = rest[body_start..body_start + close].as_bytes();
    let mut i = 0;
    while i < body.len() {
        if body[i] == b'"' {
            if let Some((key, j)) = eat_key(body, i + 1) {
                if body.get(j) == Some(&b'"') {
                    out.insert(key);
                    i = j + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

fn check_metrics_registry(
    files: &[SourceFile],
    ctx: &Context,
    out: &mut Vec<Violation>,
) {
    let snap = declared_array(&ctx.keys_src, "SNAPSHOT_KEYS");
    let prefixes = declared_array(&ctx.keys_src, "SNAPSHOT_PREFIXES");
    let bench = declared_array(&ctx.keys_src, "BENCH_KEYS");
    let declared = |k: &str| {
        snap.contains(k) || prefixes.iter().any(|p| k.starts_with(p.as_str()))
    };
    for f in files {
        if !METRICS_EMITTERS.contains(&f.rel.as_str()) {
            continue;
        }
        let (keys, fmt_prefixes) = emitted_keys(f);
        for (p, key) in keys {
            if !declared(&key) {
                emit(
                    out,
                    f,
                    "metrics-registry",
                    p,
                    format!(
                        "metrics key \"{key}\" is not declared in \
                         coordinator::metrics::keys"
                    ),
                );
            }
        }
        for (p, key) in fmt_prefixes {
            if !prefixes.contains(&key) {
                emit(
                    out,
                    f,
                    "metrics-registry",
                    p,
                    format!(
                        "dynamic metrics prefix \"{key}\" is not in \
                         SNAPSHOT_PREFIXES"
                    ),
                );
            }
        }
    }
    if let Some(schema) = &ctx.bench_schema {
        for (idx, line) in schema.lines().enumerate() {
            let key = line.trim();
            if key.is_empty() || key.starts_with('#') {
                continue;
            }
            if !(bench.contains(key) || declared(key)) {
                out.push(Violation {
                    check: "metrics-registry",
                    file: "scripts/bench_schema.txt".to_string(),
                    line: idx + 1,
                    msg: format!(
                        "bench-schema key \"{key}\" is not declared in \
                         coordinator::metrics::keys"
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------ wire-doc-drift

fn check_wire_doc_drift(
    files: &[SourceFile],
    ctx: &Context,
    out: &mut Vec<Violation>,
) {
    let Some(f) = files.iter().find(|f| f.rel == WIRE_FILE) else {
        return;
    };
    let api = ctx.api_md.as_bytes();
    let (mut keys, _) = emitted_keys(f);
    // `.get("key")` reads are wire fields too
    let text = &f.lexed.text;
    for p in find_all(text, b".get(") {
        let i = skip_ws(text, p + b".get(".len());
        let Some(i) = eat(text, i, b"\"") else { continue };
        let Some((key, i)) = eat_key(text, i) else { continue };
        let Some(i) = eat(text, i, b"\"") else { continue };
        if text.get(skip_ws(text, i)) == Some(&b')') {
            keys.push((p, key));
        }
    }
    let mut seen = BTreeSet::new();
    keys.sort_by_key(|&(p, _)| p);
    for (p, key) in keys {
        if !seen.insert(key.clone()) {
            continue;
        }
        if !contains_word(api, key.as_bytes()) {
            emit(
                out,
                f,
                "wire-doc-drift",
                p,
                format!("wire field \"{key}\" is not documented in API.md"),
            );
        }
    }
}

// --------------------------------------------------------- lock-order

/// One `lock_or_recover(..)` call site: byte position, the lock's name
/// (the last path segment of the argument, e.g. `state` for
/// `&self.state`), and — when the guard is `let`-bound — the span over
/// which it stays held (to the enclosing block's close, truncated at
/// an explicit `drop(var)`).  Statement-scoped temporaries release at
/// the `;` and hold nothing.
struct LockSite {
    pos: usize,
    name: String,
    held: Option<(usize, usize)>,
}

/// Last path segment of the lock argument at `i` (just past the open
/// paren): `&self.sched.metrics` -> `metrics`, `registry()` ->
/// `registry`.
fn lock_arg_name(code: &[u8], i: usize) -> Option<String> {
    let mut i = skip_ws(code, i);
    if let Some(j) = eat(code, i, b"&") {
        i = skip_ws(code, j);
    }
    let start = i;
    let mut j = i;
    while j < code.len()
        && (super::scan::is_ident(code[j])
            || code[j] == b'.'
            || code[j] == b':')
    {
        j += 1;
    }
    if j == start {
        return None;
    }
    let path = String::from_utf8_lossy(&code[start..j]).into_owned();
    let name = path
        .rsplit(['.', ':'])
        .next()
        .unwrap_or_default()
        .to_string();
    (!name.is_empty()).then_some(name)
}

/// End offset of the innermost `{...}` block enclosing `pos`: the
/// first `}` after `pos` whose matching `{` opened at or before it.
fn enclosing_block_end(code: &[u8], pos: usize) -> usize {
    let mut stack: Vec<usize> = Vec::new();
    for (i, &b) in code.iter().enumerate() {
        match b {
            b'{' => stack.push(i),
            b'}' => {
                let open = stack.pop().unwrap_or(0);
                if i >= pos && open <= pos {
                    return i;
                }
            }
            _ => {}
        }
    }
    code.len()
}

/// Every `lock_or_recover(` site in the file, with held spans for
/// `let`-bound guards.  Sites on test lines are excluded — test-only
/// nesting must not dictate (or violate) the shipped order.
fn lock_sites(f: &SourceFile) -> Vec<LockSite> {
    const CALL: &[u8] = b"lock_or_recover(";
    let code = &f.lexed.code;
    let mut out = Vec::new();
    for pos in find_all(code, CALL) {
        // skip `wait_*_or_recover(` lookalikes: require a non-ident
        // byte before the call
        if pos > 0 && super::scan::is_ident(code[pos - 1]) {
            continue;
        }
        if f.test_lines.contains(&f.line_at(pos)) {
            continue;
        }
        let Some(name) = lock_arg_name(code, pos + CALL.len()) else {
            continue;
        };
        // `let`-bound?  The statement prefix (text since the last
        // `;`/`{`/`}`) must bind the guard: `let [mut] var = ...`
        let stmt = code[..pos]
            .iter()
            .rposition(|&b| b == b';' || b == b'{' || b == b'}')
            .map_or(0, |k| k + 1);
        let prefix = &code[stmt..pos];
        let held = find_words(prefix, b"let")
            .into_iter()
            .next()
            .filter(|_| prefix.contains(&b'='))
            .and_then(|let_at| {
                let mut i = skip_ws(prefix, let_at + b"let".len());
                if let Some(j) = eat(prefix, i, b"mut") {
                    if prefix.get(j).is_some_and(u8::is_ascii_whitespace)
                    {
                        i = skip_ws(prefix, j);
                    }
                }
                let var_end = super::scan::eat_ident(prefix, i)?;
                let var = &prefix[i..var_end];
                // held to the enclosing block's close, or to an
                // explicit `drop(var)` that releases it early
                let mut end = enclosing_block_end(code, pos);
                let drop_pat =
                    [b"drop(" as &[u8], var, b")"].concat();
                if let Some(d) = find_all(&code[..end], &drop_pat)
                    .into_iter()
                    .find(|&d| d > pos)
                {
                    end = d;
                }
                Some((pos, end))
            });
        out.push(LockSite { pos, name, held });
    }
    out
}

/// Tree-level lock-order check: collect every ordered (outer, inner)
/// nesting of two differently-named locks, then flag each pair seen in
/// BOTH orders.  The canonical order is the majority one (ties break
/// lexicographically); violations blame the minority sites.  Purely
/// one-directional nesting — e.g. the scheduler appending to the
/// journal inside the state lock — is legal by construction.
fn check_lock_order(files: &[SourceFile], out: &mut Vec<Violation>) {
    let mut pairs: BTreeMap<(String, String), Vec<(usize, usize)>> =
        BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        let sites = lock_sites(f);
        for a in &sites {
            let Some((start, end)) = a.held else { continue };
            for b in &sites {
                if b.pos > start && b.pos < end && b.name != a.name {
                    pairs
                        .entry((a.name.clone(), b.name.clone()))
                        .or_default()
                        .push((fi, b.pos));
                }
            }
        }
    }
    let mut handled: BTreeSet<(String, String)> = BTreeSet::new();
    let keys: Vec<(String, String)> = pairs.keys().cloned().collect();
    for fwd in keys {
        let rev = (fwd.1.clone(), fwd.0.clone());
        if handled.contains(&fwd) || !pairs.contains_key(&rev) {
            continue;
        }
        handled.insert(fwd.clone());
        handled.insert(rev.clone());
        let (nf, nr) = (pairs[&fwd].len(), pairs[&rev].len());
        let canonical = if nf > nr || (nf == nr && fwd.0 <= fwd.1) {
            fwd.clone()
        } else {
            rev.clone()
        };
        let minority = if canonical == fwd { &rev } else { &fwd };
        for &(fi, pos) in &pairs[minority] {
            emit(
                out,
                &files[fi],
                "lock-order",
                pos,
                format!(
                    "lock \"{}\" acquired while \"{}\" is held, but \
                     the prevailing order is {} -> {} — inverted \
                     nesting can deadlock",
                    minority.1, minority.0, canonical.0, canonical.1
                ),
            );
        }
    }
}

// ----------------------------------------------------- unsafe-hygiene

fn check_unsafe_hygiene(f: &SourceFile, out: &mut Vec<Violation>) {
    // line -> comment text fragments on that line (block comments
    // contribute one fragment per spanned line)
    let mut comment_lines: BTreeMap<usize, Vec<&str>> = BTreeMap::new();
    for (line, text) in &f.lexed.comments {
        for (off, part) in text.split('\n').enumerate() {
            comment_lines.entry(line + off).or_default().push(part);
        }
    }
    for p in find_words(&f.lexed.code, b"unsafe") {
        let ln = f.line_at(p);
        let mut ok = false;
        let mut k = ln.saturating_sub(1);
        while k >= 1 {
            match comment_lines.get(&k) {
                Some(parts) => {
                    if parts.iter().any(|t| t.contains("SAFETY:")) {
                        ok = true;
                        break;
                    }
                    k -= 1;
                }
                None => break,
            }
        }
        if !ok {
            emit(
                out,
                f,
                "unsafe-hygiene",
                p,
                "`unsafe` without an immediately preceding \
                 `// SAFETY:` comment"
                    .to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context {
            api_md: "fields: `id`, `step`, `tokens`.".to_string(),
            keys_src: r#"
pub const SNAPSHOT_KEYS: &[&str] = &["requests_completed", "steps_saved"];
pub const SNAPSHOT_PREFIXES: &[&str] = &["halted_by_"];
pub const BENCH_KEYS: &[&str] = &["req_per_s"];
"#
            .to_string(),
            bench_schema: None,
        }
    }

    fn run_one(rel: &str, src: &str) -> Vec<Violation> {
        let files = vec![SourceFile::parse(rel, src)];
        run_all(&files, &ctx())
    }

    fn checks(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.check).collect()
    }

    // -- panic-freedom / lock-poison ---------------------------------

    #[test]
    fn panic_freedom_flags_serving_path() {
        let v = run_one(
            "coordinator/x.rs",
            "fn f() { y.unwrap(); z.expect(\"m\"); unreachable!(); }\n",
        );
        assert_eq!(
            checks(&v),
            ["panic-freedom", "panic-freedom", "panic-freedom"]
        );
    }

    #[test]
    fn panic_freedom_clean_and_out_of_scope() {
        // clean serving file
        assert!(run_one("coordinator/x.rs", "fn f() -> u8 { 0 }\n")
            .is_empty());
        // the same panics outside the serving path are not flagged
        assert!(run_one("eval/x.rs", "fn f() { y.unwrap(); }\n").is_empty());
        // test code is exempt
        let v = run_one(
            "coordinator/x.rs",
            "#[cfg(test)]\nmod t {\n fn f() { y.unwrap(); }\n}\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn panic_freedom_suppressed_by_allow() {
        let v = run_one(
            "coordinator/x.rs",
            "fn f() {\n  // lint:allow(panic-freedom): infallible here\n  \
             y.unwrap();\n}\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn lock_unwrap_is_the_poison_check_not_panic_freedom() {
        let v = run_one(
            "coordinator/x.rs",
            "fn f() { m.lock().unwrap().push(1); }\n",
        );
        assert_eq!(checks(&v), ["lock-poison"]);
        // strings mentioning unwrap are not calls
        let v = run_one(
            "coordinator/x.rs",
            "fn f() { log(\"never .unwrap() here\"); }\n",
        );
        assert!(v.is_empty());
    }

    // -- family-seal -------------------------------------------------

    #[test]
    fn family_seal_flags_once_per_match() {
        let src = "fn f(fam: Family) -> u8 {\n  match fam {\n    \
                   Family::Ddlm => 1,\n    Family::Ssd | Family::Plaid => 2,\n  \
                   }\n}\n";
        let v = run_one("exp/x.rs", src);
        assert_eq!(checks(&v), ["family-seal"]);
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn family_seal_exempts_the_seam_and_allows() {
        let src =
            "fn f(fam: Family) -> u8 { match fam { Family::Ddlm => 1, _ => 2 } }\n";
        assert!(run_one("sampler/kernel.rs", src).is_empty());
        assert_eq!(checks(&run_one("exp/x.rs", src)), ["family-seal"]);
        let suppressed = "fn f(fam: Family) -> u8 {\n  \
             // lint:allow(family-seal): table display only\n  \
             match fam { Family::Ddlm => 1, _ => 2 }\n}\n";
        assert!(run_one("exp/x.rs", suppressed).is_empty());
    }

    #[test]
    fn family_seal_blames_the_inner_match_only() {
        let src = "fn f(t: Target) -> u8 {\n  match t {\n    \
                   Target::Ar => 0,\n    Target::Dlm(fam) => match fam {\n      \
                   Family::Ddlm => 1,\n      _ => 2,\n    },\n  }\n}\n";
        let v = run_one("train/x.rs", src);
        assert_eq!(checks(&v), ["family-seal"]);
        assert_eq!(v[0].line, 4, "{v:?}");
    }

    // -- metrics-registry --------------------------------------------

    #[test]
    fn metrics_registry_flags_undeclared_keys() {
        let src = "fn f(m: &mut M) {\n  m.insert(\"requests_completed\", 1);\n  \
                   m.insert(\"mystery_key\", 2);\n  \
                   let k = format!(\"halted_by_{r}\");\n  \
                   let b = format!(\"bad_prefix_{r}\");\n}\n";
        let v = run_one("coordinator/engine.rs", src);
        assert_eq!(checks(&v), ["metrics-registry", "metrics-registry"]);
        assert!(v[0].msg.contains("mystery_key"));
        assert!(v[1].msg.contains("bad_prefix_"));
    }

    #[test]
    fn metrics_registry_ignores_non_emitter_files() {
        let src = "fn f(m: &mut M) { m.insert(\"mystery_key\", 2); }\n";
        assert!(run_one("coordinator/progress.rs", src).is_empty());
    }

    #[test]
    fn bench_schema_must_be_declared() {
        let mut c = ctx();
        c.bench_schema = Some("req_per_s\nsteps_saved\nrogue_key\n".into());
        let files =
            vec![SourceFile::parse("coordinator/engine.rs", "fn f() {}\n")];
        let v = run_all(&files, &c);
        assert_eq!(checks(&v), ["metrics-registry"]);
        assert!(v[0].msg.contains("rogue_key"));
        assert_eq!(v[0].line, 3);
    }

    // -- wire-doc-drift ----------------------------------------------

    #[test]
    fn wire_doc_drift_flags_undocumented_fields() {
        let src = "fn f(m: &mut M, j: &J) {\n  m.insert(\"id\", 1);\n  \
                   m.insert(\"undocumented_field\", 2);\n  \
                   let _ = j.get(\"step\");\n}\n";
        let v = run_one("coordinator/envelope.rs", src);
        assert_eq!(checks(&v), ["wire-doc-drift"]);
        assert!(v[0].msg.contains("undocumented_field"));
    }

    #[test]
    fn wire_doc_drift_clean_when_documented() {
        let src = "fn f(m: &mut M) { m.insert(\"tokens\", 1); }\n";
        assert!(run_one("coordinator/envelope.rs", src).is_empty());
    }

    // -- unsafe-hygiene ----------------------------------------------

    #[test]
    fn unsafe_needs_safety_comment() {
        let v = run_one(
            "runtime/x.rs",
            "fn f() { unsafe { g() } }\n",
        );
        assert_eq!(checks(&v), ["unsafe-hygiene"]);
        let clean = "fn f() {\n  // SAFETY: g has no preconditions\n  \
                     unsafe { g() }\n}\n";
        assert!(run_one("runtime/x.rs", clean).is_empty());
        // the comment may sit atop a contiguous comment block
        let stacked = "fn f() {\n  // SAFETY: g has no preconditions\n  \
                       // (and never will)\n  unsafe { g() }\n}\n";
        assert!(run_one("runtime/x.rs", stacked).is_empty());
        // lowercase "Safety:" is not the marker
        let lower = "fn f() {\n  // Safety: close enough?\n  \
                     unsafe { g() }\n}\n";
        assert_eq!(checks(&run_one("runtime/x.rs", lower)), ["unsafe-hygiene"]);
    }

    #[test]
    fn unsafe_suppressed_by_allow() {
        let src = "fn f() {\n  // lint:allow(unsafe-hygiene): documented at \
                   the module head\n  unsafe { g() }\n}\n";
        assert!(run_one("runtime/x.rs", src).is_empty());
    }

    // -- lock-order --------------------------------------------------

    #[test]
    fn lock_order_flags_inverted_pairs() {
        // alpha -> beta twice, beta -> alpha once: the minority site
        // (the beta-held alpha acquisition) is the violation
        let src = "fn f(s: &S) {\n\
                   let a = lock_or_recover(&s.alpha);\n\
                   lock_or_recover(&s.beta).push(1);\n\
                   }\n\
                   fn g(s: &S) {\n\
                   let a = lock_or_recover(&s.alpha);\n\
                   lock_or_recover(&s.beta).push(2);\n\
                   }\n\
                   fn h(s: &S) {\n\
                   let b = lock_or_recover(&s.beta);\n\
                   lock_or_recover(&s.alpha).push(3);\n\
                   }\n";
        let v = run_one("eval/x.rs", src);
        assert_eq!(checks(&v), ["lock-order"], "{v:?}");
        assert_eq!(v[0].line, 11);
        assert!(v[0].msg.contains("alpha -> beta"), "{}", v[0].msg);
    }

    #[test]
    fn lock_order_allows_one_directional_nesting() {
        // state -> journal everywhere: legal by construction
        let src = "fn f(s: &S) {\n\
                   let st = lock_or_recover(&s.state);\n\
                   lock_or_recover(&s.journal).append(1);\n\
                   }\n\
                   fn g(s: &S) {\n\
                   let st = lock_or_recover(&s.state);\n\
                   lock_or_recover(&s.journal).append(2);\n\
                   }\n";
        assert!(run_one("eval/x.rs", src).is_empty());
    }

    #[test]
    fn lock_order_ignores_statement_temporaries_and_drops() {
        // f: metrics held, then state.  g: state held but explicitly
        // dropped before the metrics TEMPORARY (no `let`) — neither
        // inversion is real, so the tree is clean.
        let src = "fn f(s: &S) {\n\
                   let m = lock_or_recover(&s.metrics);\n\
                   lock_or_recover(&s.state).tick();\n\
                   }\n\
                   fn g(s: &S) {\n\
                   let st = lock_or_recover(&s.state);\n\
                   st.tick();\n\
                   drop(st);\n\
                   lock_or_recover(&s.metrics).bump();\n\
                   }\n";
        let v = run_one("eval/x.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }
}
