//! Violation records and the machine-readable report.
//!
//! `repro analyze --report PATH` writes [`Report::to_json`] so future
//! PRs can trendline suppression debt (violations by check, by module,
//! allow-annotation count) alongside `BENCH_serving.json`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// check id, e.g. `panic-freedom`
    pub check: &'static str,
    /// path relative to `rust/src` (or `scripts/...` for schema files)
    pub file: String,
    /// 1-based line
    pub line: usize,
    pub msg: String,
}

/// The full result of one analyzer run.
pub struct Report {
    /// sorted by (check, file, line)
    pub violations: Vec<Violation>,
    /// well-formed `lint:allow` annotations across the tree
    pub allow_annotations: usize,
    /// `.rs` files scanned
    pub files_scanned: usize,
}

impl Report {
    /// `module` for the by-module rollup: the first path component
    /// (`coordinator/server.rs` -> `coordinator`), or the bare file
    /// name at the tree root (`main.rs` -> `main.rs`).
    fn module_of(file: &str) -> &str {
        file.split_once('/').map(|(m, _)| m).unwrap_or(file)
    }

    pub fn by_check(&self) -> BTreeMap<&'static str, usize> {
        let mut out = BTreeMap::new();
        for v in &self.violations {
            *out.entry(v.check).or_insert(0) += 1;
        }
        out
    }

    pub fn by_module(&self) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        for v in &self.violations {
            *out.entry(Self::module_of(&v.file).to_string()).or_insert(0) +=
                1;
        }
        out
    }

    /// Human-readable listing, one violation per line, plus a summary
    /// tail.  Empty-violation runs produce just the summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!(
                "{:<17} {}:{}  {}\n",
                v.check, v.file, v.line, v.msg
            ));
        }
        out.push_str(&format!(
            "analyze: {} violation(s), {} allow annotation(s), \
             {} file(s) scanned\n",
            self.violations.len(),
            self.allow_annotations,
            self.files_scanned,
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let violations = self
            .violations
            .iter()
            .map(|v| {
                Json::obj(vec![
                    ("check", Json::str(v.check)),
                    ("file", Json::str(v.file.clone())),
                    ("line", Json::uint(v.line as u64)),
                    ("msg", Json::str(v.msg.clone())),
                ])
            })
            .collect();
        let by_check = self
            .by_check()
            .into_iter()
            .map(|(k, n)| (k, Json::uint(n as u64)))
            .collect::<Vec<_>>();
        let by_module = self
            .by_module()
            .into_iter()
            .map(|(k, n)| (k, Json::uint(n as u64)))
            .collect::<Vec<_>>();
        let mut m = Json::obj(vec![
            ("violations", Json::Arr(violations)),
            (
                "allow_annotations",
                Json::uint(self.allow_annotations as u64),
            ),
            ("files_scanned", Json::uint(self.files_scanned as u64)),
        ])
        .into_obj();
        m.insert(
            "by_check".to_string(),
            Json::Obj(
                by_check
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            ),
        );
        m.insert(
            "by_module".to_string(),
            Json::Obj(by_module.into_iter().collect()),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            violations: vec![
                Violation {
                    check: "panic-freedom",
                    file: "coordinator/server.rs".into(),
                    line: 10,
                    msg: "x".into(),
                },
                Violation {
                    check: "panic-freedom",
                    file: "coordinator/worker.rs".into(),
                    line: 3,
                    msg: "y".into(),
                },
                Violation {
                    check: "unsafe-hygiene",
                    file: "runtime/tensor.rs".into(),
                    line: 5,
                    msg: "z".into(),
                },
            ],
            allow_annotations: 4,
            files_scanned: 7,
        }
    }

    #[test]
    fn rollups_count_by_check_and_module() {
        let r = sample();
        assert_eq!(r.by_check().get("panic-freedom"), Some(&2));
        assert_eq!(r.by_check().get("unsafe-hygiene"), Some(&1));
        assert_eq!(r.by_module().get("coordinator"), Some(&2));
        assert_eq!(r.by_module().get("runtime"), Some(&1));
    }

    #[test]
    fn json_report_shape() {
        let enc = sample().to_json().encode();
        assert!(enc.contains("\"allow_annotations\":4"));
        assert!(enc.contains("\"files_scanned\":7"));
        assert!(enc.contains("\"by_check\""));
        assert!(enc.contains("\"panic-freedom\":2"));
        assert!(enc.contains("\"coordinator\":2"));
    }

    #[test]
    fn text_render_lists_each_violation() {
        let txt = sample().render_text();
        assert_eq!(txt.lines().count(), 4);
        assert!(txt.contains("coordinator/worker.rs:3"));
        assert!(txt.contains("3 violation(s)"));
    }
}
