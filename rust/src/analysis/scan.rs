//! Byte-level pattern scanning primitives for the checks.
//!
//! The engine is pure std, so instead of a regex crate the checks
//! compose these little scanners over the lexer's blanked views.  All
//! positions are byte offsets; all patterns are ASCII.

/// Rust identifier byte (`\w` for our purposes).
pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All occurrences of `needle` in `hay`.
pub fn find_all(hay: &[u8], needle: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = super::lexer::find_bytes(hay, needle, from) {
        out.push(p);
        from = p + 1;
    }
    out
}

/// Occurrences of `needle` that stand alone as a word: no identifier
/// byte immediately before or after.
pub fn find_words(hay: &[u8], needle: &[u8]) -> Vec<usize> {
    find_all(hay, needle)
        .into_iter()
        .filter(|&p| {
            (p == 0 || !is_ident(hay[p - 1]))
                && (p + needle.len() >= hay.len()
                    || !is_ident(hay[p + needle.len()]))
        })
        .collect()
}

/// First non-whitespace position at or after `i`.
pub fn skip_ws(hay: &[u8], mut i: usize) -> usize {
    while i < hay.len() && hay[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// If `hay[i..]` starts with `lit`, the position just past it.
pub fn eat(hay: &[u8], i: usize, lit: &[u8]) -> Option<usize> {
    if hay.len() >= i + lit.len() && &hay[i..i + lit.len()] == lit {
        Some(i + lit.len())
    } else {
        None
    }
}

/// Parse a `[a-z0-9_]+` run at `i`; returns (key, end) when non-empty.
pub fn eat_key(hay: &[u8], i: usize) -> Option<(String, usize)> {
    let mut j = i;
    while j < hay.len()
        && (hay[j].is_ascii_lowercase()
            || hay[j].is_ascii_digit()
            || hay[j] == b'_')
    {
        j += 1;
    }
    if j == i {
        return None;
    }
    Some((String::from_utf8_lossy(&hay[i..j]).into_owned(), j))
}

/// Position just past a `\w+` identifier run at `i`, if non-empty.
pub fn eat_ident(hay: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    while j < hay.len() && is_ident(hay[j]) {
        j += 1;
    }
    (j > i).then_some(j)
}

/// Does `hay` contain `word` with non-identifier bytes on both sides?
/// (`\b<word>\b` — note `word` itself may contain `_`.)
pub fn contains_word(hay: &[u8], word: &[u8]) -> bool {
    !find_words(hay, word).is_empty()
}

/// End of the brace-balanced region opened at `open` (which must index
/// a `{`); the offset just past the matching `}`, or `hay.len()`.
pub fn brace_end(hay: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < hay.len() {
        match hay[j] {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    hay.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries() {
        let h = b"Family FamilyId Families xFamily";
        assert_eq!(find_words(h, b"Family"), vec![0]);
        assert_eq!(find_words(h, b"FamilyId"), vec![7]);
        assert!(!contains_word(b"Families", b"Family"));
    }

    #[test]
    fn key_and_brace_scanning() {
        let h = b"(\"steps_saved\", v)";
        let i = skip_ws(h, 1);
        let i = eat(h, i, b"\"").unwrap();
        let (k, i) = eat_key(h, i).unwrap();
        assert_eq!(k, "steps_saved");
        assert!(eat(h, i, b"\"").is_some());

        let b = b"match x { A => { 1 } B => 2 } tail";
        let open = 8;
        assert_eq!(&b[open..open + 1], b"{");
        assert_eq!(brace_end(b, open), 29);
    }
}
