//! `repro analyze` — a zero-dependency architectural lint for the
//! serving stack.
//!
//! Eight PRs of concurrency growth left the invariants that make early
//! halting correct under load — typed errors only on the wire, zero
//! match-on-family outside the kernel seam, declared metrics lanes,
//! documented frame fields, commented `unsafe` — living in ROADMAP
//! prose.  This module turns them into a CI gate: a hand-rolled lexer
//! ([`lexer`]) blanks comments and string bodies so pattern scans
//! ([`scan`]) can't be fooled by literals, per-file suppression state
//! ([`source`]) tracks `#[cfg(test)]` items and
//! `// lint:allow(<check>): <reason>` annotations, and the check
//! catalogue ([`checks`]) walks the lexed tree.  Results aggregate
//! into a [`report::Report`] with a text listing and a JSON summary.
//!
//! Everything is pure std (no `syn`, no regex): the analyzer must run
//! in the offline image, and must never grow a dependency surface the
//! code it audits doesn't have.  It reads the tree as *source* — it
//! textually parses `coordinator/metrics/keys.rs` rather than linking
//! it — so it can lint any checkout, not just the crate it ships in.
//!
//! Scope: every `.rs` under `rust/src` except `analysis/` itself (the
//! engine audits the serving stack, not its own pattern tables; its
//! own correctness is covered by the per-check fixture tests).

pub mod checks;
pub mod lexer;
pub mod report;
pub mod scan;
pub mod source;

use std::path::Path;

use anyhow::{Context as _, Result};

pub use checks::Context;
pub use report::{Report, Violation};

/// Analyze the repo rooted at `root` (the directory holding
/// `Cargo.toml`, `API.md` and `rust/src`).
pub fn analyze_tree(root: &Path) -> Result<Report> {
    let files = source::load_tree(root)?;
    let api_path = root.join("API.md");
    let api_md = std::fs::read_to_string(&api_path)
        .with_context(|| format!("read {api_path:?}"))?;
    let keys_path =
        root.join("rust/src/coordinator/metrics/keys.rs");
    let keys_src = std::fs::read_to_string(&keys_path)
        .with_context(|| format!("read {keys_path:?}"))?;
    let bench_schema =
        std::fs::read_to_string(root.join("scripts/bench_schema.txt")).ok();
    let ctx = Context { api_md, keys_src, bench_schema };
    let violations = checks::run_all(&files, &ctx);
    let allow_annotations = files.iter().map(|f| f.allow_count).sum();
    Ok(Report {
        violations,
        allow_annotations,
        files_scanned: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The gate the CI stage enforces, run in-process: the shipped
    /// tree must analyze clean (every violation either fixed or
    /// carrying a justified `lint:allow`).
    #[test]
    fn shipped_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = analyze_tree(root).expect("analyzer runs");
        assert!(
            report.violations.is_empty(),
            "unannotated violations:\n{}",
            report.render_text()
        );
        assert!(report.files_scanned > 20, "tree walk looks truncated");
    }
}
