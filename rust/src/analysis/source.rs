//! Per-file analysis state: the lexed views plus the two suppression
//! maps every check consults — `#[cfg(test)]` coverage and
//! `lint:allow` annotations.
//!
//! ## Suppression grammar
//!
//! * `#[cfg(test)]` — from the attribute, any further attributes are
//!   skipped, then the following item is brace-matched (or ends at a
//!   top-level `;`).  Every line the attribute-to-item span covers is
//!   test code: checks skip matches on those lines.
//! * `// lint:allow(<check>): <reason>` — suppresses `<check>` on the
//!   comment's own line and the line below it, so the annotation sits
//!   directly above (or at the end of) the code it excuses.  The
//!   reason is mandatory: an allow without one simply does not parse,
//!   and the violation stays.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{Context, Result};

use super::lexer::{lex, line_of, line_starts, Lexed};

/// One analyzed source file.
pub struct SourceFile {
    /// path relative to `rust/src`, with `/` separators
    pub rel: String,
    pub lexed: Lexed,
    /// byte offset of each line start
    pub starts: Vec<usize>,
    /// 1-based lines covered by `#[cfg(test)]` items
    pub test_lines: BTreeSet<usize>,
    /// check name -> 1-based lines where it is suppressed
    pub allows: BTreeMap<String, BTreeSet<usize>>,
    /// number of well-formed `lint:allow` annotations in this file
    pub allow_count: usize,
}

impl SourceFile {
    pub fn parse(rel: &str, src: &str) -> SourceFile {
        let lexed = lex(src);
        let starts = line_starts(src.as_bytes());
        let test_lines = cfg_test_lines(&lexed.code, &starts);
        let (allows, allow_count) = parse_allows(&lexed.comments);
        SourceFile {
            rel: rel.to_string(),
            lexed,
            starts,
            test_lines,
            allows,
            allow_count,
        }
    }

    /// True when a match at `line` is suppressed for `check`.
    pub fn suppressed(&self, check: &str, line: usize) -> bool {
        self.allows.get(check).is_some_and(|s| s.contains(&line))
    }

    /// 1-based line of byte offset `pos`.
    pub fn line_at(&self, pos: usize) -> usize {
        line_of(&self.starts, pos)
    }
}

/// Load every `.rs` file under `<root>/rust/src`, excluding the
/// analyzer's own sources (`analysis/`): the engine lints the serving
/// stack, not its own pattern tables.
pub fn load_tree(root: &Path) -> Result<Vec<SourceFile>> {
    let src_root = root.join("rust").join("src");
    let mut rels = Vec::new();
    walk(&src_root, &src_root, &mut rels)
        .with_context(|| format!("walk {src_root:?}"))?;
    rels.sort();
    let mut out = Vec::new();
    for rel in rels {
        if rel.starts_with("analysis/") {
            continue;
        }
        let path = src_root.join(&rel);
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?}"))?;
        out.push(SourceFile::parse(&rel, &src));
    }
    Ok(out)
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(base, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = path.strip_prefix(base) {
                out.push(
                    rel.components()
                        .map(|c| c.as_os_str().to_string_lossy())
                        .collect::<Vec<_>>()
                        .join("/"),
                );
            }
        }
    }
    Ok(())
}

/// Lines covered by `#[cfg(test)]` items (attribute through item end).
fn cfg_test_lines(code: &[u8], starts: &[usize]) -> BTreeSet<usize> {
    let mut out = BTreeSet::new();
    let needle = b"#[cfg(test)]";
    let mut from = 0;
    while let Some(a) = super::lexer::find_bytes(code, needle, from) {
        from = a + needle.len();
        let n = code.len();
        let mut j = a + needle.len();
        // skip whitespace and any further attributes
        loop {
            while j < n && code[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < n && code[j] == b'#' {
                let mut depth = 0usize;
                while j < n {
                    match code[j] {
                        b'[' => depth += 1,
                        b']' => {
                            depth = depth.saturating_sub(1);
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // brace-match the item, or stop at a top-level `;`
        let mut depth = 0usize;
        let mut end = j;
        while end < n {
            match code[end] {
                b'{' => depth += 1,
                b'}' => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        end += 1;
                        break;
                    }
                }
                b';' if depth == 0 => {
                    end += 1;
                    break;
                }
                _ => {}
            }
            end += 1;
        }
        let l0 = line_of(starts, a);
        let l1 = line_of(starts, end.min(n.saturating_sub(1)));
        out.extend(l0..=l1);
    }
    out
}

/// Parse `lint:allow(<check>): <reason>` annotations out of comments.
/// Returns the per-check suppressed-line sets and the total count of
/// well-formed annotations.
fn parse_allows(
    comments: &[(usize, String)],
) -> (BTreeMap<String, BTreeSet<usize>>, usize) {
    let mut out: BTreeMap<String, BTreeSet<usize>> = BTreeMap::new();
    let mut count = 0;
    for (line, text) in comments {
        if let Some(check) = parse_allow(text) {
            let set = out.entry(check).or_default();
            set.insert(*line);
            set.insert(*line + 1);
            count += 1;
        }
    }
    (out, count)
}

/// One annotation per comment; the check name is `[a-z0-9-]+` and a
/// non-empty reason must follow the colon.
fn parse_allow(comment: &str) -> Option<String> {
    let at = comment.find("lint:allow(")?;
    let rest = &comment[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let check = &rest[..close];
    if check.is_empty()
        || !check
            .bytes()
            .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-')
    {
        return None;
    }
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':')?.trim();
    if reason.is_empty() {
        return None;
    }
    Some(check.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_items_are_marked() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n    fn t() { x.unwrap(); }\n}\n\
                   fn also_live() {}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.test_lines.contains(&1));
        assert!(f.test_lines.contains(&2));
        assert!(f.test_lines.contains(&4));
        assert!(f.test_lines.contains(&5));
        assert!(!f.test_lines.contains(&6));
    }

    #[test]
    fn cfg_test_skips_stacked_attributes() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\nfn t() {\n}\nfn f() {}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(f.test_lines.contains(&4));
        assert!(!f.test_lines.contains(&5));
    }

    #[test]
    fn allow_covers_its_line_and_the_next() {
        let src = "// lint:allow(panic-freedom): justified here\nx.unwrap();\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(f.suppressed("panic-freedom", 1));
        assert!(f.suppressed("panic-freedom", 2));
        assert!(!f.suppressed("panic-freedom", 3));
        assert!(!f.suppressed("family-seal", 2));
        assert_eq!(f.allow_count, 1);
    }

    #[test]
    fn malformed_allows_do_not_suppress() {
        for bad in [
            "// lint:allow(panic-freedom)",        // no reason
            "// lint:allow(panic-freedom):",       // empty reason
            "// lint:allow(Panic): uppercase name",
            "// lint:allow(): anonymous",
        ] {
            let src = format!("{bad}\nx.unwrap();\n");
            let f = SourceFile::parse("a.rs", &src);
            assert!(
                !f.suppressed("panic-freedom", 2),
                "{bad:?} must not suppress"
            );
            assert_eq!(f.allow_count, 0, "{bad:?} must not count");
        }
    }
}
