//! Hand-rolled Rust source lexer for the lint engine.
//!
//! The engine needs just enough lexical structure to scan safely: where
//! comments are (for `lint:allow` / `SAFETY:` tracking), and which
//! bytes are string/char literal bodies (so `".unwrap()"` inside a log
//! message never counts as a call).  [`lex`] produces two blanked views
//! of the source plus the comment list:
//!
//! * [`Lexed::code`] — comments AND string/char bodies replaced by
//!   spaces (newlines kept, so offsets and line numbers are preserved
//!   byte-for-byte).  Checks that look for *calls* scan this view.
//! * [`Lexed::text`] — only comments blanked; string bodies kept.
//!   Checks that look for *string keys* (metrics, wire fields) scan
//!   this one.
//!
//! Handles line + nested block comments, plain strings with escapes,
//! raw strings (`r"…"`, `r#"…"#`, …), char literals (including
//! escapes), and the char-vs-lifetime ambiguity (`'a` in `&'a T`).
//! Blanking is per byte, so multi-byte UTF-8 inside a blanked region
//! collapses to ASCII spaces and every offset outside it is unchanged.

/// Lexed views of one source file.  All offsets are byte offsets into
/// the original source; both views have exactly its length.
pub struct Lexed {
    /// comments and string/char literal bodies blanked
    pub code: Vec<u8>,
    /// only comments blanked
    pub text: Vec<u8>,
    /// every comment: (1-based line of its first byte, raw text
    /// including the `//` / `/*` introducer)
    pub comments: Vec<(usize, String)>,
}

/// Byte offset of each line start; `line_of` bisects this.
pub fn line_starts(src: &[u8]) -> Vec<usize> {
    let mut starts = vec![0];
    for (i, &b) in src.iter().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

/// 1-based line containing byte offset `pos`.
pub fn line_of(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

fn blank(buf: &mut [u8], from: usize, to: usize) {
    for b in buf[from..to.min(buf.len())].iter_mut() {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

pub fn lex(src: &str) -> Lexed {
    let s = src.as_bytes();
    let n = s.len();
    let starts = line_starts(s);
    let mut code = s.to_vec();
    let mut text = s.to_vec();
    let mut comments = Vec::new();
    let mut i = 0;
    while i < n {
        let c = s[i];
        // line comment
        if c == b'/' && i + 1 < n && s[i + 1] == b'/' {
            let j = src[i..].find('\n').map(|k| i + k).unwrap_or(n);
            comments.push((
                line_of(&starts, i),
                String::from_utf8_lossy(&s[i..j]).into_owned(),
            ));
            blank(&mut code, i, j);
            blank(&mut text, i, j);
            i = j;
            continue;
        }
        // block comment (nests)
        if c == b'/' && i + 1 < n && s[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == b'/' && j + 1 < n && s[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == b'*' && j + 1 < n && s[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push((
                line_of(&starts, start),
                String::from_utf8_lossy(&s[start..j]).into_owned(),
            ));
            blank(&mut code, start, j);
            blank(&mut text, start, j);
            i = j;
            continue;
        }
        // raw string r"…" / r#"…"# / r##"…"## …
        if c == b'r' {
            let mut h = i + 1;
            while h < n && s[h] == b'#' {
                h += 1;
            }
            if h < n && s[h] == b'"' {
                let hashes = h - (i + 1);
                let mut close = vec![b'"'];
                close.extend(std::iter::repeat(b'#').take(hashes));
                let body = h + 1;
                let j = find_bytes(s, &close, body)
                    .map(|k| k + close.len())
                    .unwrap_or(n);
                blank(&mut code, i, j);
                i = j;
                continue;
            }
        }
        // plain string
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == b'\\' {
                    j += 2;
                } else if s[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            // blank the body only; keep the delimiting quotes so the
            // `text` view's key patterns still see `"key"`
            blank(&mut code, i + 1, j.saturating_sub(1));
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 1 < n && s[i + 1] == b'\\' {
                let j = src[i + 2..]
                    .find('\'')
                    .map(|k| i + 2 + k + 1)
                    .unwrap_or(n);
                blank(&mut code, i + 1, j.saturating_sub(1));
                i = j;
                continue;
            }
            if i + 2 < n && s[i + 2] == b'\'' {
                code[i + 1] = b' ';
                i += 3;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    Lexed { code, text, comments }
}

/// First occurrence of `needle` in `hay[from..]`, as an offset into
/// `hay`.
pub fn find_bytes(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|k| from + k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_str(src: &str) -> String {
        String::from_utf8(lex(src).code).expect("blanking keeps UTF-8")
    }

    #[test]
    fn strings_and_comments_are_blanked_in_code() {
        let src = "let x = \"a.unwrap()\"; // b.unwrap()\nx.unwrap();";
        let code = code_str(src);
        assert!(!code[..src.rfind('\n').unwrap()].contains(".unwrap()"));
        assert!(code.ends_with("x.unwrap();"));
        assert_eq!(code.len(), src.len());
    }

    #[test]
    fn text_view_keeps_string_bodies() {
        let src = "m.insert(\"lock_poisoned\", v); // \"not_a_key\"";
        let l = lex(src);
        let text = String::from_utf8(l.text).unwrap();
        assert!(text.contains("\"lock_poisoned\""));
        assert!(!text.contains("not_a_key"));
    }

    #[test]
    fn raw_strings_and_nested_block_comments() {
        let src = "let p = r#\"x.unwrap()\"#; /* a /* b.unwrap() */ c */";
        let code = code_str(src);
        assert!(!code.contains(".unwrap()"));
        let l = lex(src);
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].1.contains("b.unwrap()"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        assert_eq!(code_str(src), src);
    }

    #[test]
    fn char_escapes_are_blanked() {
        let src = "let c = '\\n'; let d = 'x'; y.unwrap();";
        let code = code_str(src);
        assert!(code.ends_with("y.unwrap();"));
        assert_eq!(code.len(), src.len());
    }

    #[test]
    fn comment_lines_survive_multibyte_text() {
        // a multi-byte char in a string must not shift comment lines
        let src = "let s = \"Δ%\";\nlet t = 1;\n// marker\n";
        let l = lex(src);
        assert_eq!(l.comments, vec![(3, "// marker".to_string())]);
    }

    #[test]
    fn line_of_bisects() {
        let s = b"a\nbb\nccc\n";
        let starts = line_starts(s);
        assert_eq!(line_of(&starts, 0), 1);
        assert_eq!(line_of(&starts, 2), 2);
        assert_eq!(line_of(&starts, 5), 3);
    }
}
