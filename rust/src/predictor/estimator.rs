//! Online steps-to-halt estimator.
//!
//! The paper's halting criteria watch a convergence signal (entropy /
//! KL trajectory) and stop once it crosses a threshold.  The same
//! signal is *predictive* long before the halt fires: a generation
//! whose entropy has already collapsed will halt soon, one still at
//! high entropy will not.  This module turns that observation into a
//! cheap per-family estimator — an EMA of observed halt-steps,
//! conditioned on the current entropy bucket and (when the caller
//! tracks it) the KL-slope bucket, scaled by the token-level frozen
//! fraction — that the scheduler and workers can consult in O(1) with
//! no device work.
//!
//! Two kinds of estimate:
//!
//! - [`Estimator::predict_total`] — before any steps have run, how
//!   many steps will this request take?  (Unconditional per-family
//!   EMA; cold start falls back to the schedule budget.)
//! - [`Estimator::predict_remaining`] — a slot is at step `s` with
//!   stats `st`; how many more steps?  (Bucket-conditioned EMA of
//!   "steps remaining when a completion first entered this bucket";
//!   falls back to the unconditional estimate, then the budget.)
//!
//! All state lives behind one `Mutex` so the estimator can be shared
//! (`Arc<Estimator>`) between the scheduler (admission-time reads) and
//! every worker (per-step reads, per-completion writes) without
//! touching the scheduler's state lock or any metrics lock.

use std::sync::Mutex;

use crate::halting::StepStats;
use crate::sampler::FamilyId;
use crate::util::sync::lock_or_recover;
use crate::util::json::Json;

/// Number of entropy buckets the remaining-steps estimate is
/// conditioned on.
pub const N_BUCKETS: usize = 8;

/// Geometric entropy ladder: bucket 0 is "converged" (entropy below
/// 0.02 nats/token), bucket 7 is "still noise".  Entropy is the
/// paper's primary completeness signal and is always populated in
/// [`StepStats`], unlike KL slope which needs a window.
const BUCKET_EDGES: [f32; N_BUCKETS - 1] =
    [0.02, 0.05, 0.1, 0.2, 0.4, 0.8, 1.6];

/// Map per-step stats to the entropy bucket they fall in.
pub fn bucket_for(stats: &StepStats) -> usize {
    let e = stats.entropy;
    for (i, edge) in BUCKET_EDGES.iter().enumerate() {
        if e < *edge {
            return i;
        }
    }
    N_BUCKETS - 1
}

/// Number of KL-slope buckets the remaining-steps estimate is
/// additionally conditioned on (see [`slope_bucket_for`]).
pub const N_SLOPE_BUCKETS: usize = 4;

/// Geometric |Δkl| ladder: bucket 0 is "KL trajectory flat" (the
/// klslope halting signal about to fire), bucket 3 is "still moving".
const SLOPE_EDGES: [f32; N_SLOPE_BUCKETS - 1] = [1e-4, 1e-3, 1e-2];

/// Map a per-step KL delta (`|kl_t - kl_{t-1}|`) to its slope bucket.
/// The KL *slope* is a second completeness signal orthogonal to the
/// entropy level: a slot can sit at mid entropy with a flat KL
/// trajectory (nearly done) or at the same entropy with KL still
/// falling fast (far from done).
pub fn slope_bucket_for(kl_slope: f32) -> usize {
    let s = kl_slope.abs();
    for (i, edge) in SLOPE_EDGES.iter().enumerate() {
        if s < *edge {
            return i;
        }
    }
    N_SLOPE_BUCKETS - 1
}

/// Exponential moving average that knows whether it has ever observed
/// anything (cold start must be distinguishable from "EMA happens to
/// be zero").
#[derive(Clone, Debug, Default)]
struct Ema {
    value: f64,
    n: u64,
}

impl Ema {
    fn observe(&mut self, v: f64, alpha: f64) {
        if self.n == 0 {
            self.value = v;
        } else {
            self.value += alpha * (v - self.value);
        }
        self.n += 1;
    }

    fn get(&self) -> Option<f64> {
        (self.n > 0).then_some(self.value)
    }
}

/// Per-family estimator state.
#[derive(Clone, Debug)]
struct FamilyEntry {
    /// family display name, captured at first touch (for snapshots)
    name: String,
    /// unconditional EMA of total steps-to-halt
    total_steps: Ema,
    /// EMA of steps-remaining at first entry into each entropy bucket
    remaining_by_bucket: Vec<Ema>,
    /// EMA of steps-remaining at first entry into each KL-slope bucket
    remaining_by_slope: Vec<Ema>,
    /// EMA of observed per-step device latency (batched step, ms)
    step_latency_ms: Ema,
    /// completions observed (same as `total_steps.n`, kept explicit)
    completions: u64,
}

impl FamilyEntry {
    fn new(name: String) -> FamilyEntry {
        FamilyEntry {
            name,
            total_steps: Ema::default(),
            remaining_by_bucket: vec![Ema::default(); N_BUCKETS],
            remaining_by_slope: vec![Ema::default(); N_SLOPE_BUCKETS],
            step_latency_ms: Ema::default(),
            completions: 0,
        }
    }
}

/// A steps estimate plus whether it came from observed data or is the
/// cold-start budget fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Prediction {
    /// predicted number of steps (total or remaining, per call)
    pub steps: usize,
    /// true when backed by at least one observed completion; false
    /// when it is just the schedule budget echoed back
    pub informed: bool,
}

/// Shared online steps-to-halt estimator (see module docs).
#[derive(Debug)]
pub struct Estimator {
    /// indexed by `FamilyId::index()`, grown on demand
    inner: Mutex<Vec<Option<FamilyEntry>>>,
    alpha: f64,
}

impl Default for Estimator {
    fn default() -> Estimator {
        Estimator::new()
    }
}

impl Estimator {
    /// Default smoothing (alpha 0.2): ~5 recent completions dominate,
    /// fast enough to track workload shifts, slow enough not to chase
    /// one outlier.
    pub fn new() -> Estimator {
        Estimator::with_alpha(0.2)
    }

    pub fn with_alpha(alpha: f64) -> Estimator {
        Estimator { inner: Mutex::new(Vec::new()), alpha }
    }

    fn with_entry<R>(
        &self,
        family: FamilyId,
        f: impl FnOnce(&mut FamilyEntry, f64) -> R,
    ) -> R {
        let mut g = lock_or_recover(&self.inner);
        let idx = family.index();
        if g.len() <= idx {
            g.resize(idx + 1, None);
        }
        let entry = g[idx]
            .get_or_insert_with(|| FamilyEntry::new(family.name().to_string()));
        f(entry, self.alpha)
    }

    fn read_entry<R>(
        &self,
        family: FamilyId,
        f: impl FnOnce(&FamilyEntry) -> R,
    ) -> Option<R> {
        let g = lock_or_recover(&self.inner);
        g.get(family.index()).and_then(|e| e.as_ref()).map(f)
    }

    /// Predict the total steps a fresh request will take, clamped to
    /// its schedule budget.  Cold start echoes the budget.
    pub fn predict_total(&self, family: FamilyId, budget: usize) -> Prediction {
        let ema = self
            .read_entry(family, |e| e.total_steps.get())
            .flatten();
        match ema {
            Some(v) => Prediction {
                steps: (v.round().max(0.0) as usize).min(budget),
                informed: true,
            },
            None => Prediction { steps: budget, informed: false },
        }
    }

    /// Predict the steps remaining for a slot at `step` with current
    /// `stats`, clamped to `[0, budget - step]`.  Prefers the
    /// entropy-bucket-conditioned EMA, falls back to the unconditional
    /// total minus executed steps, then to the remaining budget.
    pub fn predict_remaining(
        &self,
        family: FamilyId,
        stats: &StepStats,
        step: usize,
        budget: usize,
    ) -> Prediction {
        self.predict_remaining_with(family, stats, None, 0.0, step, budget)
    }

    /// [`Self::predict_remaining`] with the two extra conditioning
    /// features the worker tracks per slot:
    ///
    /// - `kl_slope` — the last per-step KL delta; when available, the
    ///   slope-bucket EMA is averaged with the entropy-bucket EMA
    ///   (two orthogonal completeness signals beat either alone);
    /// - `frozen_fraction` — fraction of positions pinned by
    ///   token-level freezes; a sequence 40% frozen has roughly 60%
    ///   of its denoising left, so informed estimates scale by
    ///   `1 - frozen_fraction`.
    pub fn predict_remaining_with(
        &self,
        family: FamilyId,
        stats: &StepStats,
        kl_slope: Option<f32>,
        frozen_fraction: f32,
        step: usize,
        budget: usize,
    ) -> Prediction {
        let cap = budget.saturating_sub(step);
        let bucket = bucket_for(stats);
        let sbucket = kl_slope.map(slope_bucket_for);
        let (by_bucket, by_slope, total) = self
            .read_entry(family, |e| {
                (
                    e.remaining_by_bucket[bucket].get(),
                    sbucket.and_then(|s| e.remaining_by_slope[s].get()),
                    e.total_steps.get(),
                )
            })
            .unwrap_or((None, None, None));
        let scale = 1.0 - f64::from(frozen_fraction.clamp(0.0, 1.0));
        let informed = match (by_bucket, by_slope) {
            (Some(a), Some(b)) => Some((a + b) / 2.0),
            (a, b) => a.or(b),
        };
        if let Some(v) = informed {
            return Prediction {
                steps: ((v * scale).round().max(0.0) as usize).min(cap),
                informed: true,
            };
        }
        if let Some(v) = total {
            let rem = (v.round().max(0.0) as usize).saturating_sub(step);
            let rem = (rem as f64 * scale).round() as usize;
            return Prediction { steps: rem.min(cap), informed: true };
        }
        Prediction { steps: cap, informed: false }
    }

    /// Record a finished generation: `total_steps` executed, and for
    /// every entropy bucket the generation visited, the step at which
    /// it *first* entered that bucket (so the bucket EMA learns
    /// "steps remaining from here").
    pub fn observe_completion(
        &self,
        family: FamilyId,
        total_steps: usize,
        visited: &[(usize, usize)],
    ) {
        self.observe_completion_full(family, total_steps, visited, &[]);
    }

    /// [`Self::observe_completion`] plus the KL-slope bucket entries:
    /// `slope_visited` lists `(slope_bucket, entry_step)` for every
    /// slope bucket the generation first entered, feeding the
    /// slope-conditioned EMA that
    /// [`Self::predict_remaining_with`] consults.
    pub fn observe_completion_full(
        &self,
        family: FamilyId,
        total_steps: usize,
        visited: &[(usize, usize)],
        slope_visited: &[(usize, usize)],
    ) {
        self.with_entry(family, |e, alpha| {
            e.total_steps.observe(total_steps as f64, alpha);
            e.completions += 1;
            for &(bucket, entry_step) in visited {
                if bucket < N_BUCKETS {
                    let rem = total_steps.saturating_sub(entry_step);
                    e.remaining_by_bucket[bucket].observe(rem as f64, alpha);
                }
            }
            for &(bucket, entry_step) in slope_visited {
                if bucket < N_SLOPE_BUCKETS {
                    let rem = total_steps.saturating_sub(entry_step);
                    e.remaining_by_slope[bucket].observe(rem as f64, alpha);
                }
            }
        });
    }

    /// Record one observed batched-step device latency.
    pub fn observe_step_latency(&self, family: FamilyId, ms: f64) {
        if ms.is_finite() && ms >= 0.0 {
            self.with_entry(family, |e, alpha| {
                e.step_latency_ms.observe(ms, alpha);
            });
        }
    }

    /// Current per-step latency estimate (ms), if any step has been
    /// observed for this family.
    pub fn step_latency_ms(&self, family: FamilyId) -> Option<f64> {
        self.read_entry(family, |e| e.step_latency_ms.get()).flatten()
    }

    /// Completions observed for a family (0 when cold).
    pub fn observations(&self, family: FamilyId) -> u64 {
        self.read_entry(family, |e| e.completions).unwrap_or(0)
    }

    /// Per-family estimator state for the metrics snapshot:
    /// `{ "<fam>": { observations, ema_total_steps, step_latency_ms,
    ///    buckets: [..] } }` — only families with at least one write.
    pub fn snapshot_json(&self) -> Json {
        let g = lock_or_recover(&self.inner);
        let mut fields = Vec::new();
        for e in g.iter().flatten() {
            let buckets: Vec<Json> = e
                .remaining_by_bucket
                .iter()
                .map(|b| match b.get() {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                })
                .collect();
            let slope_buckets: Vec<Json> = e
                .remaining_by_slope
                .iter()
                .map(|b| match b.get() {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                })
                .collect();
            let mut obj = vec![
                ("observations", Json::uint(e.completions)),
                ("buckets", Json::Arr(buckets)),
                ("slope_buckets", Json::Arr(slope_buckets)),
            ];
            if let Some(v) = e.total_steps.get() {
                obj.push(("ema_total_steps", Json::num(v)));
            }
            if let Some(v) = e.step_latency_ms.get() {
                obj.push(("step_latency_ms", Json::num(v)));
            }
            fields.push((e.name.clone(), Json::obj(obj)));
        }
        let mut m = std::collections::BTreeMap::new();
        for (k, v) in fields {
            m.insert(k, v);
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::registry;

    fn fam() -> FamilyId {
        registry::resolve("ddlm").unwrap()
    }

    fn stats(entropy: f32) -> StepStats {
        StepStats { entropy, ..Default::default() }
    }

    #[test]
    fn cold_start_echoes_budget() {
        let est = Estimator::new();
        let p = est.predict_total(fam(), 600);
        assert_eq!(p, Prediction { steps: 600, informed: false });
        let r = est.predict_remaining(fam(), &stats(0.5), 100, 600);
        assert_eq!(r, Prediction { steps: 500, informed: false });
        assert_eq!(est.observations(fam()), 0);
        assert!(est.step_latency_ms(fam()).is_none());
    }

    #[test]
    fn ema_converges_to_observed_halt_steps() {
        let est = Estimator::new();
        for _ in 0..50 {
            est.observe_completion(fam(), 120, &[]);
        }
        let p = est.predict_total(fam(), 600);
        assert!(p.informed);
        assert_eq!(p.steps, 120);
        // budget clamps the estimate
        assert_eq!(est.predict_total(fam(), 80).steps, 80);
        assert_eq!(est.observations(fam()), 50);
    }

    #[test]
    fn ema_tracks_workload_shift() {
        let est = Estimator::new();
        for _ in 0..30 {
            est.observe_completion(fam(), 100, &[]);
        }
        for _ in 0..30 {
            est.observe_completion(fam(), 300, &[]);
        }
        let p = est.predict_total(fam(), 600);
        // alpha 0.2 over 30 observations: essentially converged to 300
        assert!(p.steps > 290 && p.steps <= 300, "steps={}", p.steps);
    }

    #[test]
    fn bucket_edges_are_monotonic() {
        assert_eq!(bucket_for(&stats(0.001)), 0);
        assert_eq!(bucket_for(&stats(0.03)), 1);
        assert_eq!(bucket_for(&stats(5.0)), N_BUCKETS - 1);
        let mut prev = 0;
        for e in [0.01, 0.04, 0.07, 0.15, 0.3, 0.6, 1.2, 2.0] {
            let b = bucket_for(&stats(e));
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn bucket_conditioned_remaining_beats_unconditional() {
        let est = Estimator::new();
        // generations run 200 steps total; they first hit low entropy
        // (bucket 0) at step 180 → 20 steps remaining from there
        for _ in 0..40 {
            est.observe_completion(fam(), 200, &[(0, 180), (7, 0)]);
        }
        let near_done = est.predict_remaining(fam(), &stats(0.001), 150, 600);
        assert!(near_done.informed);
        assert_eq!(near_done.steps, 20);
        // high-entropy slot at step 0 → bucket 7 learned 200 remaining
        let fresh = est.predict_remaining(fam(), &stats(5.0), 0, 600);
        assert_eq!(fresh.steps, 200);
        // unvisited bucket falls back to unconditional total - step
        let mid = est.predict_remaining(fam(), &stats(0.3), 50, 600);
        assert!(mid.informed);
        assert_eq!(mid.steps, 150);
    }

    #[test]
    fn remaining_is_clamped_to_remaining_budget() {
        let est = Estimator::new();
        est.observe_completion(fam(), 500, &[(7, 0)]);
        let p = est.predict_remaining(fam(), &stats(5.0), 90, 100);
        assert_eq!(p.steps, 10);
        // step past budget → zero, never underflow
        let z = est.predict_remaining(fam(), &stats(5.0), 200, 100);
        assert_eq!(z.steps, 0);
    }

    #[test]
    fn slope_bucket_conditioning_and_averaging() {
        assert_eq!(slope_bucket_for(0.0), 0);
        assert_eq!(slope_bucket_for(-5e-4), 1); // |Δkl| ladder
        assert_eq!(slope_bucket_for(5e-3), 2);
        assert_eq!(slope_bucket_for(1.0), N_SLOPE_BUCKETS - 1);

        let est = Estimator::new();
        // entropy bucket 0 says 20 remaining, slope bucket 0 says 40
        for _ in 0..40 {
            est.observe_completion_full(
                fam(),
                200,
                &[(0, 180)],
                &[(0, 160)],
            );
        }
        // slope unavailable → entropy bucket alone
        let e_only = est.predict_remaining_with(
            fam(), &stats(0.001), None, 0.0, 100, 600,
        );
        assert_eq!(e_only.steps, 20);
        // both signals → averaged: (20 + 40) / 2
        let both = est.predict_remaining_with(
            fam(), &stats(0.001), Some(1e-5), 0.0, 100, 600,
        );
        assert!(both.informed);
        assert_eq!(both.steps, 30);
        // slope bucket alone (entropy bucket 4 never visited)
        let s_only = est.predict_remaining_with(
            fam(), &stats(0.3), Some(1e-5), 0.0, 100, 600,
        );
        assert_eq!(s_only.steps, 40);
    }

    #[test]
    fn frozen_fraction_scales_informed_estimates() {
        let est = Estimator::new();
        for _ in 0..40 {
            est.observe_completion(fam(), 200, &[(0, 100)]);
        }
        // bucket 0 learned 100 remaining; half the positions frozen →
        // half the denoising left
        let half = est.predict_remaining_with(
            fam(), &stats(0.001), None, 0.5, 50, 600,
        );
        assert_eq!(half.steps, 50);
        // fully frozen → nothing left, regardless of the EMA
        let done = est.predict_remaining_with(
            fam(), &stats(0.001), None, 1.0, 50, 600,
        );
        assert_eq!(done.steps, 0);
        // out-of-range fractions clamp instead of exploding
        let neg = est.predict_remaining_with(
            fam(), &stats(0.001), None, -3.0, 50, 600,
        );
        assert_eq!(neg.steps, 100);
        // cold start ignores the scale: the budget echo is not an
        // informed estimate
        let cold = Estimator::new();
        let p = cold.predict_remaining_with(
            fam(), &stats(0.5), None, 0.5, 100, 600,
        );
        assert_eq!(p, Prediction { steps: 500, informed: false });
    }

    #[test]
    fn step_latency_ema() {
        let est = Estimator::new();
        est.observe_step_latency(fam(), 10.0);
        assert_eq!(est.step_latency_ms(fam()), Some(10.0));
        for _ in 0..50 {
            est.observe_step_latency(fam(), 20.0);
        }
        let v = est.step_latency_ms(fam()).unwrap();
        assert!((v - 20.0).abs() < 0.5, "v={v}");
        // non-finite observations are ignored
        est.observe_step_latency(fam(), f64::NAN);
        assert!(est.step_latency_ms(fam()).unwrap().is_finite());
    }

    #[test]
    fn snapshot_lists_touched_families_only() {
        let est = Estimator::new();
        let snap = est.snapshot_json();
        assert_eq!(snap.encode(), "{}");
        est.observe_completion(fam(), 42, &[(3, 10)]);
        let Json::Obj(m) = est.snapshot_json() else { panic!() };
        assert_eq!(m.len(), 1);
        let entry = m.get("ddlm").unwrap();
        assert_eq!(entry.get("observations").and_then(Json::as_u64), Some(1));
        assert!(entry.get("ema_total_steps").is_some());
    }
}
