//! Slot-packing policy for the continuous-batching admission loop.
//!
//! When a worker has more queued work than free slots, the order in
//! which `next_for` hands out requests decides tail latency.  FIFO is
//! the safe default.  SRPT (shortest-predicted-remaining-time-first)
//! uses the [`super::Estimator`]'s per-family steps prediction to pull
//! short generations ahead of long ones within the same priority
//! class — the classic mean-latency-optimal discipline, made possible
//! here because the halting signal gives a usable length estimate.
//! Priority classes still dominate: SRPT only reorders candidates of
//! equal priority, and ties keep FIFO order (stable).

/// Queue-ordering discipline used by the scheduler's `next_for`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PackingMode {
    /// strict submission order within a priority class (default)
    #[default]
    Fifo,
    /// shortest-predicted-remaining-steps first within a priority
    /// class; ties and cold-start fall back to FIFO / budget order
    Srpt,
}

impl PackingMode {
    /// Parse a CLI value (`"fifo"` / `"srpt"`).
    pub fn parse(s: &str) -> Option<PackingMode> {
        match s {
            "fifo" => Some(PackingMode::Fifo),
            "srpt" => Some(PackingMode::Srpt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackingMode::Fifo => "fifo",
            PackingMode::Srpt => "srpt",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(PackingMode::parse("fifo"), Some(PackingMode::Fifo));
        assert_eq!(PackingMode::parse("srpt"), Some(PackingMode::Srpt));
        assert_eq!(PackingMode::parse("lifo"), None);
        assert_eq!(PackingMode::Srpt.name(), "srpt");
        assert_eq!(PackingMode::default(), PackingMode::Fifo);
    }
}
