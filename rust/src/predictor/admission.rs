//! Deadline feasibility check for admission control.
//!
//! At submit time the scheduler knows three things: the request's
//! `deadline_ms`, its step budget, and (via the shared
//! [`Estimator`]) how many steps requests of this family actually
//! take and how long one batched device step costs.  Multiplying the
//! two estimates gives a predicted wall time; a deadline the fleet
//! cannot possibly meet is rejected up front with a typed
//! `infeasible_deadline` error instead of burning device steps on a
//! request whose submitter will see `deadline_exceeded` anyway.
//!
//! The check is deliberately conservative about cold starts: with no
//! observed per-step latency there is no basis for a wall-time
//! estimate, so the verdict is [`Feasibility::Unknown`] and the
//! request is admitted.  (Steps-side cold start is fine — the budget
//! upper-bounds the step count, making the estimate pessimistic, and
//! a pessimistic estimate that still fits the deadline is safe to
//! admit.)  Queue wait IS modelled: the scheduler passes the
//! predicted steps already queued ahead for this family
//! (`queued_steps_ahead`), so a deadline that would be met on an idle
//! fleet but cannot survive the current backlog is rejected up front
//! too — a fast device behind a deep queue is just as infeasible as a
//! slow device.

use crate::sampler::FamilyId;

use super::estimator::Estimator;

/// Verdict of the admission-time deadline check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Feasibility {
    /// predicted wall time fits inside the deadline
    Feasible,
    /// predicted wall time exceeds the deadline — reject
    Infeasible {
        /// predicted wall time (ms) that exceeded the deadline
        predicted_ms: f64,
    },
    /// no latency data yet for this family — admit (cold start)
    Unknown,
}

/// Check whether `deadline_ms` is feasible for a request of `family`
/// with step budget `budget`, given `queued_steps_ahead` predicted
/// steps already waiting in this family's queue (the expected queue
/// wait prices in at the same per-step latency as the request's own
/// steps; pass 0 for an idle-fleet check).
pub fn check(
    est: &Estimator,
    family: FamilyId,
    budget: usize,
    queued_steps_ahead: usize,
    deadline_ms: f64,
) -> Feasibility {
    let Some(per_step_ms) = est.step_latency_ms(family) else {
        return Feasibility::Unknown;
    };
    let steps = est.predict_total(family, budget).steps;
    let predicted_ms = (steps + queued_steps_ahead) as f64 * per_step_ms;
    if predicted_ms > deadline_ms {
        Feasibility::Infeasible { predicted_ms }
    } else {
        Feasibility::Feasible
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::registry;

    fn fam() -> FamilyId {
        registry::resolve("ddlm").unwrap()
    }

    #[test]
    fn cold_start_is_unknown() {
        let est = Estimator::new();
        assert_eq!(check(&est, fam(), 600, 0, 1.0), Feasibility::Unknown);
    }

    #[test]
    fn trained_estimator_splits_feasible_from_infeasible() {
        let est = Estimator::new();
        for _ in 0..20 {
            est.observe_completion(fam(), 100, &[]);
            est.observe_step_latency(fam(), 2.0);
        }
        // ~100 steps × ~2ms = ~200ms predicted
        assert_eq!(check(&est, fam(), 600, 0, 1_000.0), Feasibility::Feasible);
        match check(&est, fam(), 600, 0, 50.0) {
            Feasibility::Infeasible { predicted_ms } => {
                assert!(predicted_ms > 150.0 && predicted_ms < 250.0);
            }
            other => panic!("expected infeasible, got {other:?}"),
        }
    }

    #[test]
    fn steps_cold_start_uses_budget_pessimistically() {
        let est = Estimator::new();
        // latency known, steps unknown → budget upper-bounds steps
        est.observe_step_latency(fam(), 10.0);
        // 600-step budget × 10ms = 6000ms predicted
        assert!(matches!(
            check(&est, fam(), 600, 0, 1_000.0),
            Feasibility::Infeasible { .. }
        ));
        assert_eq!(check(&est, fam(), 600, 0, 10_000.0), Feasibility::Feasible);
    }

    #[test]
    fn deep_queue_makes_a_fast_device_infeasible() {
        let est = Estimator::new();
        for _ in 0..20 {
            est.observe_completion(fam(), 100, &[]);
            est.observe_step_latency(fam(), 2.0);
        }
        // idle fleet: ~200ms predicted, 500ms deadline → feasible
        assert_eq!(check(&est, fam(), 600, 0, 500.0), Feasibility::Feasible);
        // same request behind 1000 queued predicted steps: the queue
        // alone costs ~2000ms — the fast device cannot save it
        match check(&est, fam(), 600, 1_000, 500.0) {
            Feasibility::Infeasible { predicted_ms } => {
                assert!(predicted_ms > 2_000.0, "{predicted_ms}");
            }
            other => panic!("expected infeasible, got {other:?}"),
        }
        // a deadline generous enough for queue + own steps still admits
        assert_eq!(
            check(&est, fam(), 600, 1_000, 10_000.0),
            Feasibility::Feasible
        );
    }
}
