//! Completeness predictor: the paper's halting signal as a
//! *scheduling primitive*.
//!
//! The halting criteria ([`crate::halting`]) watch entropy/KL
//! trajectories and stop generation when text is complete.  This
//! subsystem observes the same trajectories fleet-wide and turns them
//! into predictions — "this request will halt in ~N steps" — that
//! drive three serving features:
//!
//! - **deadline-aware admission** ([`admission`]): reject requests
//!   whose `deadline_ms` cannot be met given (predicted steps +
//!   predicted steps queued ahead) × observed per-step latency
//!   (typed `infeasible_deadline` error);
//! - **SRPT slot packing** ([`packing`]): when slots are scarce,
//!   run the shortest-predicted generation first;
//! - **wire-visible estimates**: v1 `progress`/`done` frames carry
//!   `predicted_steps_remaining` / `predicted_total_steps` so clients
//!   can implement smart client-side halts.
//!
//! Everything hangs off one shared [`Estimator`] (`Arc`ed between the
//! scheduler and all workers); [`PredictorConfig`] on
//! `EngineConfig` gates each feature independently, all off by
//! default so the fleet's behavior is bit-identical unless opted in.

pub mod admission;
pub mod estimator;
pub mod packing;

pub use admission::{check as check_feasibility, Feasibility};
pub use estimator::{
    bucket_for, slope_bucket_for, Estimator, Prediction, N_BUCKETS,
    N_SLOPE_BUCKETS,
};
pub use packing::PackingMode;

/// Per-engine predictor feature gates (all default off).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredictorConfig {
    /// emit `predicted_steps_remaining` / `predicted_total_steps` on
    /// v1 progress and done frames
    pub enabled: bool,
    /// reject infeasible `deadline_ms` at submit (`infeasible_deadline`)
    pub admission: bool,
    /// queue-ordering discipline for slot packing
    pub packing: PackingMode,
}

impl PredictorConfig {
    /// True when any feature needs the estimator to learn — the
    /// engine builds and feeds an [`Estimator`] iff this holds.
    pub fn active(&self) -> bool {
        self.enabled || self.admission || self.packing == PackingMode::Srpt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_fully_off() {
        let c = PredictorConfig::default();
        assert!(!c.enabled && !c.admission);
        assert_eq!(c.packing, PackingMode::Fifo);
        assert!(!c.active());
    }

    #[test]
    fn any_gate_activates_the_estimator() {
        assert!(PredictorConfig { enabled: true, ..Default::default() }
            .active());
        assert!(PredictorConfig { admission: true, ..Default::default() }
            .active());
        assert!(PredictorConfig {
            packing: PackingMode::Srpt,
            ..Default::default()
        }
        .active());
    }
}
