//! Parameter store: named tensors for one model family, checkpointing, and
//! manifest-driven input assembly.
//!
//! Artifacts list their surviving HLO parameters by name (jax prunes unused
//! inputs at lowering, e.g. `tw.w` in non-DDLM step functions), so the
//! correct calling convention is *assembly by name*: walk the artifact's
//! input specs in order, pull parameters from the store and data tensors
//! from the caller.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::pbin;
use crate::runtime::{ArtifactSpec, Tensor};

#[derive(Clone, Debug)]
pub struct ParamStore {
    pub family: String,
    pub tensors: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Load the initial parameters exported by `make artifacts`.
    pub fn load_init(artifact_dir: &str, family: &str) -> Result<ParamStore> {
        let path = format!("{artifact_dir}/{family}_init.pbin");
        Ok(ParamStore {
            family: family.to_string(),
            tensors: pbin::read(&path)?,
        })
    }

    /// Load a checkpoint written by [`ParamStore::save`].
    pub fn load(path: impl AsRef<Path>, family: &str) -> Result<ParamStore> {
        Ok(ParamStore {
            family: family.to_string(),
            tensors: pbin::read(path)?,
        })
    }

    /// [`ParamStore::load_init`] through the process-wide artifact
    /// cache: the `.pbin` image is memory-mapped once and shared by
    /// every worker of the family; parsing reads straight off the
    /// mapping, no per-worker file read.
    pub fn load_init_cached(
        artifact_dir: &str,
        family: &str,
    ) -> Result<ParamStore> {
        Self::load_cached(format!("{artifact_dir}/{family}_init.pbin"), family)
    }

    /// [`ParamStore::load`] through the process-wide artifact cache —
    /// the rebind hot path: a checkpoint hot-swap of N same-family
    /// workers maps the weights once, then each rebind parses from the
    /// warm shared mapping.
    pub fn load_cached(
        path: impl AsRef<Path>,
        family: &str,
    ) -> Result<ParamStore> {
        use crate::runtime::artifact_cache::{global, CacheKey};
        let path = path.as_ref();
        let key = CacheKey::checkpoint(family, path);
        let binding = global().bind(&key, path)?;
        let tensors = pbin::parse(binding.bytes())?;
        // the binding drops here: checkpoint bytes are one-shot parse
        // inputs, so they stay cached-but-unpinned (LRU-evictable)
        Ok(ParamStore {
            family: family.to_string(),
            tensors,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        pbin::write(path, &self.tensors)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("param {name} missing in {}", self.family))
    }

    /// Total scalar parameter count (reporting).
    pub fn n_params(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    /// Assemble the full input vector for an artifact: parameters from the
    /// store (by name), everything else from `data` (by name, consumed).
    pub fn assemble(
        &self,
        spec: &ArtifactSpec,
        mut data: BTreeMap<String, Tensor>,
    ) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            if let Some(t) = self.tensors.get(&input.name) {
                out.push(t.clone());
            } else if let Some(t) = data.remove(input.name.as_str()) {
                if t.shape() != input.shape.as_slice() {
                    bail!(
                        "{}: data input {} shape {:?} != spec {:?}",
                        spec.name,
                        input.name,
                        t.shape(),
                        input.shape
                    );
                }
                out.push(t);
            } else {
                bail!(
                    "{}: input {} provided neither by params nor data",
                    spec.name,
                    input.name
                );
            }
        }
        if !data.is_empty() {
            bail!(
                "{}: unused data inputs {:?}",
                spec.name,
                data.keys().collect::<Vec<_>>()
            );
        }
        Ok(out)
    }

    /// Replace parameter values from artifact outputs named `p.<name>`
    /// (training-step convention).
    pub fn update_from_outputs(
        &mut self,
        spec: &ArtifactSpec,
        outputs: &[Tensor],
    ) -> Result<()> {
        for (i, oname) in spec.outputs.iter().enumerate() {
            if let Some(pname) = oname.strip_prefix("p.") {
                if self.tensors.contains_key(pname) {
                    self.tensors.insert(pname.to_string(), outputs[i].clone());
                }
            }
        }
        Ok(())
    }
}

/// Adam optimizer state mirrored on the rust side (travels through the
/// train artifact as plain tensors).
#[derive(Clone, Debug)]
pub struct OptState {
    pub m: BTreeMap<String, Tensor>,
    pub v: BTreeMap<String, Tensor>,
    pub count: f32,
}

impl OptState {
    pub fn zeros_like(params: &ParamStore) -> OptState {
        let zeros = |t: &Tensor| Tensor::zeros_f32(t.shape());
        OptState {
            m: params
                .tensors
                .iter()
                .map(|(k, t)| (k.clone(), zeros(t)))
                .collect(),
            v: params
                .tensors
                .iter()
                .map(|(k, t)| (k.clone(), zeros(t)))
                .collect(),
            count: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, InputSpec};

    fn fake_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            family: "ddlm".into(),
            role: "step".into(),
            batch: 1,
            seq_len: 4,
            inputs: vec![
                InputSpec {
                    name: "emb".into(),
                    shape: vec![2, 2],
                    dtype: Dtype::F32,
                },
                InputSpec {
                    name: "x_t".into(),
                    shape: vec![1, 4],
                    dtype: Dtype::F32,
                },
            ],
            outputs: vec!["p.emb".into(), "loss".into()],
        }
    }

    fn fake_store() -> ParamStore {
        let mut tensors = BTreeMap::new();
        tensors.insert("emb".to_string(), Tensor::f32(&[2, 2], vec![1.0; 4]));
        ParamStore {
            family: "ddlm".into(),
            tensors,
        }
    }

    #[test]
    fn assemble_orders_params_then_data() {
        let store = fake_store();
        let spec = fake_spec();
        let mut data = BTreeMap::new();
        data.insert("x_t".to_string(), Tensor::f32(&[1, 4], vec![9.0; 4]));
        let inputs = store.assemble(&spec, data).unwrap();
        assert_eq!(inputs.len(), 2);
        assert_eq!(inputs[0].as_f32().unwrap(), &[1.0; 4]);
        assert_eq!(inputs[1].as_f32().unwrap(), &[9.0; 4]);
    }

    #[test]
    fn assemble_rejects_missing_and_extra() {
        let store = fake_store();
        let spec = fake_spec();
        assert!(store.assemble(&spec, BTreeMap::new()).is_err());
        let mut data = BTreeMap::new();
        data.insert("x_t".to_string(), Tensor::f32(&[1, 4], vec![0.0; 4]));
        data.insert("bogus".to_string(), Tensor::scalar_f32(0.0));
        assert!(store.assemble(&spec, data).is_err());
    }

    #[test]
    fn assemble_rejects_bad_shape() {
        let store = fake_store();
        let spec = fake_spec();
        let mut data = BTreeMap::new();
        data.insert("x_t".to_string(), Tensor::f32(&[4], vec![0.0; 4]));
        assert!(store.assemble(&spec, data).is_err());
    }

    #[test]
    fn update_from_outputs_overwrites_params() {
        let mut store = fake_store();
        let spec = fake_spec();
        let outs = vec![
            Tensor::f32(&[2, 2], vec![5.0; 4]),
            Tensor::scalar_f32(0.1),
        ];
        store.update_from_outputs(&spec, &outs).unwrap();
        assert_eq!(store.get("emb").unwrap().as_f32().unwrap(), &[5.0; 4]);
    }

    #[test]
    fn opt_state_shapes_match() {
        let store = fake_store();
        let opt = OptState::zeros_like(&store);
        assert_eq!(opt.m["emb"].shape(), store.get("emb").unwrap().shape());
        assert_eq!(opt.count, 0.0);
    }
}
