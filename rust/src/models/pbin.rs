//! PBIN reader/writer — rust twin of `python/compile/pbin.py`.
//!
//! Format (little-endian):
//!   magic  : 6 bytes  b"PBIN1\n"
//!   count  : u32
//!   tensor*: u32 name_len | name | u8 dtype (0=f32,1=i32)
//!            | u32 ndim | u64*ndim dims | raw data

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::Tensor;

const MAGIC: &[u8; 6] = b"PBIN1\n";

pub fn read(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;
    parse(&data)
}

pub fn parse(data: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    if data.len() < 10 || &data[..6] != MAGIC {
        bail!("bad PBIN magic");
    }
    let mut off = 6usize;
    let rd_u32 = |data: &[u8], off: &mut usize| -> Result<u32> {
        if *off + 4 > data.len() {
            bail!("truncated PBIN (u32 at {off})");
        }
        let v = u32::from_le_bytes(data[*off..*off + 4].try_into().unwrap());
        *off += 4;
        Ok(v)
    };
    let count = rd_u32(data, &mut off)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = rd_u32(data, &mut off)? as usize;
        if off + nlen > data.len() {
            bail!("truncated PBIN (name)");
        }
        let name = std::str::from_utf8(&data[off..off + nlen])
            .context("name utf8")?
            .to_string();
        off += nlen;
        if off >= data.len() {
            bail!("truncated PBIN (dtype)");
        }
        let dtype = data[off];
        off += 1;
        let ndim = rd_u32(data, &mut off)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            if off + 8 > data.len() {
                bail!("truncated PBIN (dim)");
            }
            dims.push(u64::from_le_bytes(
                data[off..off + 8].try_into().unwrap(),
            ) as usize);
            off += 8;
        }
        let numel: usize = dims.iter().product::<usize>().max(
            if dims.is_empty() { 1 } else { 0 },
        );
        let nbytes = numel * 4;
        if off + nbytes > data.len() {
            bail!("truncated PBIN (data for {name})");
        }
        let raw = &data[off..off + nbytes];
        off += nbytes;
        let tensor = match dtype {
            0 => {
                let mut v = vec![0f32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    v[i] = f32::from_le_bytes(c.try_into().unwrap());
                }
                Tensor::f32(&dims, v)
            }
            1 => {
                let mut v = vec![0i32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    v[i] = i32::from_le_bytes(c.try_into().unwrap());
                }
                Tensor::i32(&dims, v)
            }
            other => bail!("unknown PBIN dtype {other}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

pub fn write(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        match t {
            Tensor::F32 { shape, data } => {
                buf.push(0);
                buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
                for &d in shape {
                    buf.extend_from_slice(&(d as u64).to_le_bytes());
                }
                for x in data {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            Tensor::I32 { shape, data } => {
                buf.push(1);
                buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
                for &d in shape {
                    buf.extend_from_slice(&(d as u64).to_le_bytes());
                }
                for x in data {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]));
        m.insert("idx".to_string(), Tensor::i32(&[3], vec![-5, 0, 7]));
        m.insert("s".to_string(), Tensor::scalar_f32(2.5));
        let dir = std::env::temp_dir().join("pbin_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pbin");
        write(&p, &m).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOTPBINxxxxxxx").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::f32(&[4], vec![1., 2., 3., 4.]));
        let dir = std::env::temp_dir().join("pbin_test_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pbin");
        write(&p, &m).unwrap();
        let data = std::fs::read(&p).unwrap();
        for cut in [7usize, 12, data.len() - 3] {
            assert!(parse(&data[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn reads_python_written_init_if_present() {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/ddlm_init.pbin");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = read(&p).unwrap();
        assert!(m.contains_key("emb"));
        let emb = &m["emb"];
        assert_eq!(emb.shape(), &[512, 64]);
    }
}
