//! Model bindings: parameter stores (PBIN), per-family artifact glue.

pub mod pbin;
pub mod store;
