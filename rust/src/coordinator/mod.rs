//! The L3 coordination contribution: request router, continuous batcher
//! with early-exit slot recycling, TCP JSON-lines server, metrics.

pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;

pub use engine::{start, EngineConfig, EngineHandle};
pub use request::{GenRequest, GenResponse};
pub use server::{Client, Server};
