//! The L3 coordination contribution: a sharded serving stack around the
//! paper's early-halting payoff.
//!
//! Layers (one module each):
//!
//! * [`scheduler`] — admission control: a bounded queue with priority
//!   classes (high/normal/low, optional per-class bounds), per-request
//!   deadlines, explicit cancellation, backpressure (full queue or
//!   class ⇒ typed `overloaded` rejection instead of unbounded growth),
//!   per-family request routing, and boundary validation (overlong
//!   prefix or unserved family ⇒ `invalid_request`, in-flight id reuse
//!   ⇒ `duplicate_id`, zero-step budgets answered without a worker).
//! * [`worker`] — N worker shards, each an OS thread owning one PJRT
//!   runtime and one batched `Session` (continuous batching with
//!   early-exit slot recycling).  Shards may bind different compiled
//!   batch sizes *and different model families*: small-batch shards
//!   soak latency-sensitive traffic, large-batch shards soak
//!   throughput, and one fleet serves a heterogeneous family mix.
//! * [`engine`] — thin composition: `start()` wires scheduler + workers
//!   (`EngineConfig::worker_specs` = `(family, batch)` per shard);
//!   [`EngineHandle`] exposes `submit`/`try_submit`/`generate`,
//!   `cancel(id)`, merged fleet `metrics()`, and `shutdown()`.
//! * [`server`] — TCP JSON-lines front-end (wire fields `priority`,
//!   `deadline_ms`, `family`, control cmds `metrics`/`cancel`) with a
//!   joinable `Server::stop()`.
//! * [`metrics`] — per-worker metrics merged into one fleet snapshot:
//!   queue-depth and slot-occupancy gauges, per-priority latency
//!   histograms, `rejected_overloaded`/`cancelled`/`deadline_exceeded`
//!   counters, per-reason `halted_by_*`, and per-family lanes
//!   (`requests_completed_<fam>`, `latency_p50_ms_<fam>`, ...).

pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use engine::{start, EngineConfig, EngineHandle, EngineJoin};
pub use request::{GenRequest, GenResponse, Priority};
pub use scheduler::{CancelOutcome, GenOutcome, Scheduler, ServeError};
pub use server::{Client, Server};
