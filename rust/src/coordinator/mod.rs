//! The L3 coordination contribution: a sharded serving stack around the
//! paper's early-halting payoff.
//!
//! Layers (one module each):
//!
//! * [`scheduler`] — admission control: a bounded queue with priority
//!   classes (high/normal/low, optional per-class bounds), per-request
//!   deadlines, explicit cancellation, backpressure (full queue or
//!   class ⇒ typed `overloaded` rejection instead of unbounded growth),
//!   per-family request routing, boundary validation (overlong
//!   prefix or unserved family ⇒ `invalid_request`, in-flight id reuse
//!   ⇒ `duplicate_id`, zero-step budgets answered without a worker),
//!   the graceful client `halt` verb (finalize with the current
//!   decode, `halt_reason:"client"`), per-request progress
//!   subscribers, and the opt-in completeness-predictor hooks
//!   (deadline-aware admission rejecting with `infeasible_deadline`,
//!   SRPT slot packing) fed by [`crate::predictor::Estimator`].
//! * [`worker`] — N worker shards, each an OS thread owning one PJRT
//!   runtime and one batched `Session` (continuous batching with
//!   early-exit slot recycling).  Shards may bind different compiled
//!   batch sizes *and different model families*: small-batch shards
//!   soak latency-sensitive traffic, large-batch shards soak
//!   throughput, and one fleet serves a heterogeneous family mix.
//! * [`engine`] — thin composition: `start()` wires scheduler + workers
//!   (`EngineConfig::worker_specs` = `(family, batch)` per shard);
//!   [`EngineHandle`] exposes `submit`/`try_submit`/`generate`,
//!   `cancel(id)`, merged fleet `metrics()`, and `shutdown()`.
//! * [`envelope`] — the versioned (v1) wire protocol: typed frames
//!   (`submit`/`progress`/`done`/`error`/`cancel`/`halt`/`metrics`/
//!   `rebind`) over a multiplexed connection, with an error taxonomy and
//!   per-line legacy autodetect (lines without a `"v"` key take the
//!   one-shot path unchanged).
//! * [`server`] — TCP JSON-lines front-end: per-connection writer
//!   thread multiplexing legacy replies, v1 acks and streaming
//!   forwarders; legacy wire fields `priority`, `deadline_ms`,
//!   `family` and control cmds `metrics`/`cancel` behave exactly as
//!   before; joinable `Server::stop()`.
//! * [`client`] — the first-class typed [`Client`] (submit / stream /
//!   halt / cancel / metrics) shared by the CLI, examples, benches and
//!   tests.
//! * [`journal`] — write-ahead admission log: queued admissions and
//!   terminal resolutions appended as length-prefixed, checksummed
//!   records (fsync-batched, torn-tail tolerant); on restart the
//!   engine replays it and re-admits exactly the incomplete set.
//! * [`metrics`] — per-worker metrics merged into one fleet snapshot:
//!   queue-depth and slot-occupancy gauges, per-priority latency
//!   histograms, `rejected_overloaded`/`cancelled`/`deadline_exceeded`
//!   counters, per-reason `halted_by_*` (client halts appear as
//!   `halted_by_client`), per-family lanes
//!   (`requests_completed_<fam>`, `latency_p50_ms_<fam>`, ...), and
//!   the per-family schedule envelope under `"families"`.
//!
//! Families on the wire are open: request/response `family` strings
//! resolve through `sampler::registry`, so kernels registered at
//! runtime serve end-to-end without touching the `Family` enum.

pub mod client;
pub mod engine;
pub mod envelope;
pub mod journal;
pub mod metrics;
pub mod progress;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use client::{CancelAck, Client, HaltAck, RebindAck, RemoteError};
pub use engine::{start, EngineConfig, EngineHandle, EngineJoin};
pub use envelope::{Command, Event, PROTOCOL_VERSION};
pub use request::{GenRequest, GenResponse, Priority, ProgressEvent};
pub use progress::DEFAULT_PROGRESS_BUFFER;
pub use journal::{Journal, Replay};
pub use scheduler::{
    CancelOutcome, FleetHealth, GenOutcome, ProgressRx, ProgressTx,
    RebindOrder, RebindReport, ResumeState, Scheduler, ServeError,
};
pub use server::Server;
