//! TCP JSON-lines front-end for the engine (std-thread substitute for the
//! usual tokio stack — DESIGN.md §8).
//!
//! Protocol: one JSON object per line.
//!   request  : GenRequest JSON (see `request.rs`), or `{"cmd":"metrics"}`
//!   response : GenResponse JSON / metrics object / `{"error": "..."}`
//!
//! The request's `criterion` field carries a halting-policy spec string
//! (`"entropy:0.25"`, `"any(entropy:0.25,patience:20:0)"`, ... — see the
//! `halting` module docs); early-halted responses carry the firing
//! primitive in `halt_reason`, and the metrics snapshot exposes
//! per-reason `halted_by_*` counters.
//!
//! Each connection gets a handler thread; handlers forward requests to the
//! engine handle (cheap mpsc clone) and stream responses back in arrival
//! order per connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::engine::EngineHandle;
use super::request::GenRequest;
use crate::log_info;
use crate::util::json::Json;

pub struct Server {
    pub addr: String,
    accept_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start accepting (port 0 = ephemeral; the chosen address is
    /// in `self.addr`).
    pub fn start(bind: &str, engine: EngineHandle) -> Result<Server> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        log_info!("server listening on {addr}");
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                match stream {
                    Ok(s) => {
                        let eng = engine.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_conn(s, eng) {
                                crate::util::log::log(
                                    crate::util::log::Level::Debug,
                                    "server",
                                    &format!("conn closed: {e}"),
                                );
                            }
                        });
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            accept_thread: Some(accept_thread),
        })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // the accept thread exits when the process does; detach it
        if let Some(t) = self.accept_thread.take() {
            drop(t);
        }
    }
}

fn handle_conn(stream: TcpStream, engine: EngineHandle) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::str(format!("parse: {e}")))]),
            Ok(j) => {
                if j.get("cmd").and_then(Json::as_str) == Some("metrics") {
                    engine.metrics().unwrap_or(Json::Null)
                } else {
                    match GenRequest::from_json(&j) {
                        Err(e) => Json::obj(vec![(
                            "error",
                            Json::str(format!("bad request: {e}")),
                        )]),
                        Ok(req) => match engine.generate(req) {
                            Ok(resp) => resp.to_json(),
                            Err(e) => Json::obj(vec![(
                                "error",
                                Json::str(format!("engine: {e}")),
                            )]),
                        },
                    }
                }
            }
        };
        writer.write_all(reply.encode().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    let _ = peer;
    Ok(())
}

/// Minimal blocking client for examples / benches / tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
        })
    }

    pub fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        self.writer.write_all(msg.encode().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("response parse: {e}"))
    }

    pub fn generate(
        &mut self,
        req: &GenRequest,
    ) -> Result<super::request::GenResponse> {
        let j = self.roundtrip(&req.to_json())?;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        super::request::GenResponse::from_json(&j)
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![("cmd", Json::str("metrics"))]))
    }
}
