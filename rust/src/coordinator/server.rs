//! TCP JSON-lines front-end for the engine (std-thread substitute for the
//! usual tokio stack — DESIGN.md §8).
//!
//! Protocol: one JSON object per line.
//!
//!   request  : GenRequest JSON (see `request.rs`) —
//!              `{"id":1,"steps":200,"criterion":"entropy:0.25",
//!                "priority":"high","deadline_ms":2500,"family":"ssd"}`.
//!              `priority` ("high"|"normal"|"low", default normal) picks
//!              the admission class; `deadline_ms` (optional) bounds the
//!              request's total wall-clock time; `family` (optional:
//!              "ddlm"|"ssd"|"plaid", default = the fleet's default
//!              family) routes to a worker shard of that model family —
//!              responses echo the serving family.
//!   control  : `{"cmd":"metrics"}` — merged fleet metrics snapshot
//!              `{"cmd":"cancel","id":7}` — cancel a queued or running
//!              request; replies `{"id":7,"cancelled":true,
//!              "state":"queued"|"running"|"not_found"}`
//!   response : GenResponse JSON, or a typed serving error
//!              `{"id":1,"error":"overloaded"|"cancelled"|
//!                "deadline_exceeded"|"unavailable"|"invalid_request"|
//!                "duplicate_id"}`, or
//!              `{"error":"parse: ..."}` for malformed lines.
//!              `invalid_request` rejects a prefix longer than the
//!              fleet's compiled seq_len or a `family` no live worker
//!              serves; `duplicate_id` rejects an id that is already
//!              queued or running (ids route cancellation, so they must
//!              be unique while in flight).
//!
//! The request's `criterion` field carries a halting-policy spec string
//! (`"entropy:0.25"`, `"any(entropy:0.25,patience:20:0)"`, ... — see the
//! `halting` module docs); early-halted responses carry the firing
//! primitive in `halt_reason`, and the metrics snapshot exposes
//! per-reason `halted_by_*` counters.
//!
//! Each connection gets a handler thread; handlers forward requests to
//! the engine handle (cheap clone of the scheduler front-end) and stream
//! responses back in arrival order per connection.  `Server::stop()` (or
//! drop) closes the listener and joins the accept thread.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::engine::EngineHandle;
use super::request::GenRequest;
use crate::log_info;
use crate::util::json::Json;

pub struct Server {
    pub addr: String,
    accept_thread: Option<JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
}

impl Server {
    /// Bind and start accepting (port 0 = ephemeral; the chosen address is
    /// in `self.addr`).
    pub fn start(bind: &str, engine: EngineHandle) -> Result<Server> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        log_info!("server listening on {addr}");
        let stopping = Arc::new(AtomicBool::new(false));
        let stop_flag = stopping.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let eng = engine.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_conn(s, eng) {
                                crate::util::log::log(
                                    crate::util::log::Level::Debug,
                                    "server",
                                    &format!("conn closed: {e}"),
                                );
                            }
                        });
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            accept_thread: Some(accept_thread),
            stopping,
        })
    }

    /// Stop accepting and join the accept thread.  In-flight connection
    /// handlers finish their current line and exit when their client
    /// disconnects.  Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        let Some(t) = self.accept_thread.take() else { return };
        self.stopping.store(true, Ordering::SeqCst);
        // poke the listener so the blocking accept observes the flag;
        // fall back to loopback for wildcard binds and retry briefly —
        // only detach (leak) the thread if the listener is unreachable
        let loopback = self
            .addr
            .rsplit_once(':')
            .map(|(_, port)| format!("127.0.0.1:{port}"));
        for attempt in 0..3 {
            let woke = TcpStream::connect(&self.addr).is_ok()
                || loopback
                    .as_deref()
                    .is_some_and(|a| TcpStream::connect(a).is_ok());
            if woke {
                let _ = t.join();
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(
                10 << attempt,
            ));
        }
        crate::util::log::log(
            crate::util::log::Level::Debug,
            "server",
            "stop: listener unreachable; detaching accept thread",
        );
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(stream: TcpStream, engine: EngineHandle) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => {
                Json::obj(vec![("error", Json::str(format!("parse: {e}")))])
            }
            Ok(j) => handle_line(&j, &engine),
        };
        writer.write_all(reply.encode().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn handle_line(j: &Json, engine: &EngineHandle) -> Json {
    match j.get("cmd").and_then(Json::as_str) {
        Some("metrics") => engine.metrics().unwrap_or(Json::Null),
        Some("cancel") => match j.get("id").and_then(Json::as_f64) {
            None => {
                Json::obj(vec![("error", Json::str("cancel: missing id"))])
            }
            Some(id) => {
                let outcome = engine.cancel(id as u64);
                Json::obj(vec![
                    ("id", Json::num(id)),
                    ("cancelled", Json::Bool(outcome.found())),
                    ("state", Json::str(outcome.as_str())),
                ])
            }
        },
        Some(other) => {
            Json::obj(vec![("error", Json::str(format!("unknown cmd {other:?}")))])
        }
        None => match GenRequest::from_json(j) {
            Err(e) => Json::obj(vec![(
                "error",
                Json::str(format!("bad request: {e}")),
            )]),
            Ok(req) => {
                let id = req.id;
                match engine.submit(req).recv() {
                    Ok(Ok(resp)) => resp.to_json(),
                    Ok(Err(serve_err)) => Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("error", Json::str(serve_err.as_str())),
                    ]),
                    Err(_) => Json::obj(vec![(
                        "error",
                        Json::str("engine: reply channel closed"),
                    )]),
                }
            }
        },
    }
}

/// Minimal blocking client for examples / benches / tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
        })
    }

    pub fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        self.writer.write_all(msg.encode().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("response parse: {e}"))
    }

    /// Blocking generate; typed serving errors (`overloaded`,
    /// `cancelled`, `deadline_exceeded`, ...) surface as `Err` with the
    /// error string in the message.
    pub fn generate(
        &mut self,
        req: &GenRequest,
    ) -> Result<super::request::GenResponse> {
        let j = self.roundtrip(&req.to_json())?;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        super::request::GenResponse::from_json(&j)
    }

    /// Cancel a queued or running request by id (typically from a second
    /// connection); returns the raw `{"cancelled":..,"state":..}` reply.
    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![
            ("cmd", Json::str("cancel")),
            ("id", Json::num(id as f64)),
        ]))
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![("cmd", Json::str("metrics"))]))
    }
}
