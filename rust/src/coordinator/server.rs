//! TCP JSON-lines front-end for the engine (std-thread substitute for the
//! usual tokio stack — DESIGN.md §8).
//!
//! One JSON object per line, with **per-line protocol autodetect**:
//!
//! * a line carrying `"v":1` is a **v1 envelope frame** (see
//!   [`super::envelope`]) — submits stream back interleaved `progress`
//!   / `done` / `error` frames over the shared per-connection writer,
//!   and the control verbs `cancel` (abort), `halt` (graceful
//!   finalize: a normal `done` with the current x0 decode and
//!   `halt_reason:"client"`), `metrics` and `rebind` (admin: live
//!   worker re-bind, answered asynchronously once the drain/rebuild
//!   completes) are answered with typed ack frames;
//! * a bare object without a `v` key is the **legacy one-shot
//!   protocol**, served unchanged: a GenRequest JSON line
//!   (`{"id":1,"steps":200,"criterion":"entropy:0.25","priority":
//!   "high","deadline_ms":2500,"family":"ssd"}`) answers with exactly
//!   one GenResponse line in arrival order, and the control lines
//!   `{"cmd":"metrics"}` / `{"cmd":"cancel","id":7}` behave as they
//!   always have.  Pre-envelope clients keep working byte-for-byte.
//!
//! Typed serving errors (`overloaded`, `cancelled`,
//! `deadline_exceeded`, `unavailable`, `invalid_request`,
//! `duplicate_id`, `infeasible_deadline`, `internal`) come back as
//! `{"id":N,"error":CODE}` on the legacy path and as `error` frames on
//! v1; errors carrying a machine-readable detail (e.g. `internal` /
//! `"token_download_failed"`) put it in `message`.  A legacy request
//! line that fails validation answers
//! `{"error":"invalid_request","message":...}` (plus `"id"` when one
//! was parseable); malformed JSON answers `{"error":"parse: ..."}`.
//! While the fleet is degraded or browned out, v1 `overloaded` /
//! `unavailable` error frames additionally carry a `retry_after_ms`
//! backoff hint (absent from a healthy fleet).
//!
//! Each connection gets a reader thread (this handler) plus one writer
//! thread draining an mpsc channel — the multiplexing point where
//! legacy replies, v1 acks and per-request streaming forwarders all
//! meet.  Legacy lines are still handled synchronously in arrival
//! order; v1 submits spawn a forwarder thread so many requests stream
//! concurrently on one connection.  A dropped connection cancels the
//! v1 requests it still has in flight — streamed ones when their next
//! progress frame fails to write, every one (streamed or not) when the
//! reader sees the disconnect — so a dead client never burns the rest
//! of its step budget.  `Server::stop()` (or drop) closes the listener
//! and joins the accept thread.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::engine::EngineHandle;
use super::envelope::{self, Command, Event};
use super::request::GenRequest;
use super::DEFAULT_PROGRESS_BUFFER;
use crate::log_info;
use crate::util::sync::lock_or_recover;
use crate::util::json::Json;

pub struct Server {
    pub addr: String,
    accept_thread: Option<JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
}

impl Server {
    /// Bind and start accepting (port 0 = ephemeral; the chosen address is
    /// in `self.addr`).
    pub fn start(bind: &str, engine: EngineHandle) -> Result<Server> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        log_info!("server listening on {addr}");
        let stopping = Arc::new(AtomicBool::new(false));
        let stop_flag = stopping.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let eng = engine.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_conn(s, eng) {
                                crate::util::log::log(
                                    crate::util::log::Level::Debug,
                                    "server",
                                    &format!("conn closed: {e}"),
                                );
                            }
                        });
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            accept_thread: Some(accept_thread),
            stopping,
        })
    }

    /// Stop accepting and join the accept thread.  In-flight connection
    /// handlers finish their current line and exit when their client
    /// disconnects.  Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        let Some(t) = self.accept_thread.take() else { return };
        self.stopping.store(true, Ordering::SeqCst);
        // poke the listener so the blocking accept observes the flag;
        // fall back to loopback for wildcard binds and retry briefly —
        // only detach (leak) the thread if the listener is unreachable
        let loopback = self
            .addr
            .rsplit_once(':')
            .map(|(_, port)| format!("127.0.0.1:{port}"));
        for attempt in 0..3 {
            let woke = TcpStream::connect(&self.addr).is_ok()
                || loopback
                    .as_deref()
                    .is_some_and(|a| TcpStream::connect(a).is_ok());
            if woke {
                let _ = t.join();
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(
                10 << attempt,
            ));
        }
        crate::util::log::log(
            crate::util::log::Level::Debug,
            "server",
            "stop: listener unreachable; detaching accept thread",
        );
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection frame sink: encoded lines from the reader loop, the
/// v1 control path, and every streaming forwarder thread funnel through
/// one channel into one writer thread, so concurrent frames never
/// interleave bytes mid-line.
type ConnTx = mpsc::Sender<String>;

/// v1 request ids this connection submitted whose terminal frame has
/// not been relayed yet; drained with `engine.cancel` when the reader
/// observes the disconnect (see `handle_conn`).
type Inflight = Arc<Mutex<HashSet<u64>>>;

fn handle_conn(stream: TcpStream, engine: EngineHandle) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let (tx, rx) = mpsc::channel::<String>();
    // writer thread: lives until every sender (reader loop + streaming
    // forwarders) is gone, so a long-running streamed request keeps its
    // line open even after the reader saw EOF
    std::thread::spawn(move || {
        for line in rx {
            // deterministic chaos: sever the socket between frames,
            // exactly like a client vanishing mid-stream — the reader
            // loop sees EOF and cancels this connection's in-flight
            // requests
            if crate::util::fault::check("conn_drop").is_some() {
                let _ = writer.shutdown(Shutdown::Both);
                break;
            }
            if writer
                .write_all(line.as_bytes())
                .and_then(|()| writer.write_all(b"\n"))
                .is_err()
            {
                break; // client gone; senders observe the closed channel
            }
        }
    });
    let reader = BufReader::new(stream);
    let inflight: Inflight = Arc::new(Mutex::new(HashSet::new()));
    let mut read_err = None;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                read_err = Some(e);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(&line) {
            Err(e) => {
                let reply =
                    Json::obj(vec![("error", Json::str(format!("parse: {e}")))]);
                if tx.send(reply.encode()).is_err() {
                    break;
                }
            }
            Ok(j) if envelope::is_envelope(&j) => {
                handle_frame(&j, &engine, &tx, &inflight);
            }
            Ok(j) => {
                // legacy one-shot path: synchronous, arrival order
                let reply = handle_line(&j, &engine);
                if tx.send(reply.encode()).is_err() {
                    break;
                }
            }
        }
    }
    // the client disconnected (EOF or error): nobody can ever read the
    // decodes of — or halt — the v1 requests still in flight on this
    // connection, so cancel them instead of burning their remaining
    // step budgets (each counts toward the `cancelled` metric).  Ids
    // whose reply raced the disconnect are already out of the set, and
    // a cancel of an already-finished id is a typed no-op.
    let stale: Vec<u64> = lock_or_recover(&inflight).drain().collect();
    for id in stale {
        engine.cancel(id);
    }
    match read_err {
        Some(e) => Err(e.into()),
        None => Ok(()),
    }
}

/// Dispatch one v1 envelope frame.  Control verbs answer inline;
/// submits spawn a forwarder thread that streams the request's progress
/// events and terminal frame to the connection writer.
fn handle_frame(
    j: &Json,
    engine: &EngineHandle,
    tx: &ConnTx,
    inflight: &Inflight,
) {
    let cmd = match Command::from_json(j) {
        Ok(cmd) => cmd,
        Err(e) => {
            let ev = Event::Error {
                id: j.get("id").and_then(Json::as_u64),
                code: e.code().to_string(),
                message: Some(e.to_string()),
                retry_after_ms: None,
            };
            let _ = tx.send(ev.to_json().encode());
            return;
        }
    };
    match cmd {
        Command::Metrics => {
            let data = engine.metrics().unwrap_or(Json::Null);
            let _ = tx.send(Event::Metrics(data).to_json().encode());
        }
        Command::Cancel { id } => {
            let outcome = engine.cancel(id);
            let ev = Event::CancelAck {
                id,
                cancelled: outcome.found(),
                state: outcome.as_str().to_string(),
            };
            let _ = tx.send(ev.to_json().encode());
        }
        Command::Halt { id } => {
            let outcome = engine.halt(id);
            let ev = Event::HaltAck {
                id,
                found: outcome.found(),
                state: outcome.as_str().to_string(),
            };
            let _ = tx.send(ev.to_json().encode());
        }
        Command::Rebind {
            worker,
            family,
            batch,
            checkpoint,
        } => {
            // resolve the family name at the wire boundary so a typo
            // answers a typed refusal instead of reaching the engine
            let fam = match family.as_deref() {
                Some(name) => match crate::sampler::registry::resolve(name) {
                    Some(f) => Some(f),
                    None => {
                        let ev = Event::RebindAck {
                            worker,
                            ok: false,
                            message: Some(format!("unknown family {name:?}")),
                            family: None,
                            batch: None,
                            drained: None,
                            rebind_ms: None,
                        };
                        let _ = tx.send(ev.to_json().encode());
                        return;
                    }
                },
                None => None,
            };
            // the rebind blocks until the worker has drained and
            // rebuilt (or refused / failed-and-reverted) — run it off
            // the reader thread so the connection stays responsive
            let tx = tx.clone();
            let engine = engine.clone();
            std::thread::spawn(move || {
                let ev = match engine.rebind(worker, fam, batch, checkpoint) {
                    Ok(report) => Event::RebindAck {
                        worker: report.worker,
                        ok: true,
                        message: None,
                        family: Some(report.family.name().to_string()),
                        batch: Some(report.batch),
                        drained: Some(report.drained),
                        rebind_ms: Some(report.rebind_ms),
                    },
                    Err(msg) => Event::RebindAck {
                        worker,
                        ok: false,
                        message: Some(msg),
                        family: None,
                        batch: None,
                        drained: None,
                        rebind_ms: None,
                    },
                };
                let _ = tx.send(ev.to_json().encode());
            });
        }
        Command::Submit(req) => {
            let id = req.id;
            let wants_progress = req.progress_every.is_some();
            // bounded drop-oldest ring: a slow client sheds its oldest
            // progress frames (counted in `progress_dropped`) instead
            // of growing an unbounded queue or stalling the worker
            let (prog_tx, prog_rx) =
                super::progress::channel(DEFAULT_PROGRESS_BUFFER);
            // register BEFORE submitting so a disconnect racing the
            // submit still finds the id in the set
            lock_or_recover(&inflight).insert(id);
            let reply_rx = engine
                .submit_with_progress(*req, wants_progress.then_some(prog_tx));
            let tx = tx.clone();
            let engine = engine.clone();
            let inflight = inflight.clone();
            // one forwarder per streamed request: drains progress until
            // the request drops its sender (end of stream), then relays
            // the terminal outcome — so within one request, progress
            // frames always precede the done/error frame
            std::thread::spawn(move || {
                for ev in prog_rx {
                    if tx.send(Event::Progress(ev).to_json().encode()).is_err()
                    {
                        // the connection is gone: nobody can ever read
                        // this stream's decode OR halt it, so cancel
                        // instead of burning the remaining step budget
                        // for a dead client (frees the slot within one
                        // device step)
                        engine.cancel(id);
                        break;
                    }
                }
                let outcome = reply_rx.recv();
                lock_or_recover(&inflight).remove(&id);
                let frame = match outcome {
                    Ok(Ok(resp)) => Event::Done(resp),
                    Ok(Err(serve_err)) => Event::Error {
                        id: Some(id),
                        code: serve_err.as_str().to_string(),
                        message: serve_err.detail().map(str::to_string),
                        // capacity answers from a degraded fleet carry
                        // a backoff hint; a healthy fleet's error
                        // frames stay byte-identical
                        retry_after_ms: match serve_err {
                            super::scheduler::ServeError::Overloaded
                            | super::scheduler::ServeError::Unavailable => {
                                engine.retry_after_ms()
                            }
                            _ => None,
                        },
                    },
                    Err(_) => Event::Error {
                        id: Some(id),
                        code: "internal".to_string(),
                        message: Some("reply channel closed".to_string()),
                        retry_after_ms: None,
                    },
                };
                let _ = tx.send(frame.to_json().encode());
            });
        }
    }
}

fn handle_line(j: &Json, engine: &EngineHandle) -> Json {
    match j.get("cmd").and_then(Json::as_str) {
        Some("metrics") => engine.metrics().unwrap_or(Json::Null),
        Some("cancel") => match j.get("id").and_then(Json::as_u64) {
            None => {
                Json::obj(vec![("error", Json::str("cancel: missing id"))])
            }
            Some(id) => {
                let outcome = engine.cancel(id);
                Json::obj(vec![
                    ("id", Json::uint(id)),
                    ("cancelled", Json::Bool(outcome.found())),
                    ("state", Json::str(outcome.as_str())),
                ])
            }
        },
        Some(other) => {
            Json::obj(vec![("error", Json::str(format!("unknown cmd {other:?}")))])
        }
        None => match GenRequest::from_json(j) {
            Err(e) => {
                // typed rejection (satisfying e.g. the malformed-prefix
                // contract: reject, never truncate); the human-readable
                // cause rides in `message`
                let mut fields = vec![
                    ("error", Json::str("invalid_request")),
                    ("message", Json::str(format!("{e:#}"))),
                ];
                if let Some(id) = j.get("id").and_then(Json::as_u64) {
                    fields.push(("id", Json::uint(id)));
                }
                Json::obj(fields)
            }
            Ok(req) => {
                let id = req.id;
                match engine.submit(req).recv() {
                    Ok(Ok(resp)) => resp.to_json(),
                    Ok(Err(serve_err)) => {
                        let mut fields = vec![
                            ("id", Json::uint(id)),
                            ("error", Json::str(serve_err.as_str())),
                        ];
                        if let Some(d) = serve_err.detail() {
                            fields.push(("message", Json::str(d)));
                        }
                        Json::obj(fields)
                    }
                    Err(_) => Json::obj(vec![(
                        "error",
                        Json::str("engine: reply channel closed"),
                    )]),
                }
            }
        },
    }
}
