//! Serving metrics: latency histograms, step accounting, steps-saved,
//! per-reason halt counters — the numbers behind the paper's headline
//! "10-40% faster generation".
//!
//! Ownership after the scheduler/worker split: every worker owns one
//! `Metrics` value (behind an `Arc<Mutex<..>>`), and the scheduler owns
//! one more for admission-side events (preflight completions, overload
//! rejections, queued-side cancels and deadline drops).  The engine's
//! `/metrics` snapshot is the [`Metrics::merge`] of all of them plus
//! queue-depth / slot-occupancy gauges — see `EngineHandle::metrics`.
//!
//! Every completed request — preflight-resolved or worker-stepped — goes
//! through the single [`Metrics::record_completion`] path, so the two
//! cannot drift in what they count.  Completions additionally feed a
//! per-family lane ([`FamilyMetrics`]) keyed by the serving kernel, so
//! a heterogeneous fleet's snapshot reports throughput/latency/halt
//! counters per model family (`requests_completed_<fam>`,
//! `latency_p50_ms_<fam>`, `halted_by_<reason>_<fam>`, ...).

pub mod keys;

use std::collections::BTreeMap;
use std::time::Instant;

use super::request::{GenResponse, Priority};
use crate::sampler::FamilyId;

/// Fixed-bucket latency histogram (milliseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1ms .. ~2min, roughly x2 per bucket
        let bounds: Vec<f64> = (0..18).map(|i| 1.0 * 2f64.powi(i)).collect();
        Histogram {
            counts: vec![0; bounds.len() + 1],
            bounds,
            sum: 0.0,
            n: 0,
            max: 0.0,
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram in (identical fixed bounds by construction).
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.n += other.n;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket upper bounds (conservative).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }
}

/// Per-family completion/latency/halt accounting — one lane per model
/// family served, so a heterogeneous fleet's snapshot can split its
/// throughput claims the way the efficiency literature asks for.
/// Surfaced in the JSON snapshot as `requests_completed_<fam>`,
/// `latency_p50_ms_<fam>`, `halted_by_<reason>_<fam>`, ...
#[derive(Clone, Debug, Default)]
pub struct FamilyMetrics {
    pub requests_completed: u64,
    pub halted_early: u64,
    pub steps_executed: u64,
    pub steps_saved: u64,
    pub latency_ms: Histogram,
    /// early halts per policy reason within this family
    pub halted_by: BTreeMap<String, u64>,
    /// sum of |predicted_total_steps − steps_executed| over graded
    /// predictions; divide by `predictions` for the family's MAE
    pub prediction_err_steps: f64,
    /// number of graded predictions in this lane
    pub predictions: u64,
    /// positions freeze-pinned by token-level policies (the paper's
    /// per-token early exit, counted across completions)
    pub tokens_frozen: u64,
    /// token-steps the device spent stepping already-frozen positions
    /// — numerator of `frozen_step_fraction_<fam>`
    pub frozen_token_steps: u64,
    /// token-steps executed by completions that froze at least one
    /// position (`steps_executed × L` summed over those completions) —
    /// the fraction's denominator
    pub token_steps_total: u64,
    /// token-level budget saving: at each freeze, newly-frozen
    /// positions × the request's remaining step budget
    pub token_steps_saved: u64,
}

impl FamilyMetrics {
    fn record(&mut self, resp: &GenResponse) {
        self.requests_completed += 1;
        self.steps_executed += resp.steps_executed as u64;
        self.steps_saved +=
            resp.steps_budget.saturating_sub(resp.steps_executed) as u64;
        if resp.halted_early {
            if let Some(reason) = &resp.halt_reason {
                self.halted_early += 1;
                *self.halted_by.entry(reason.clone()).or_insert(0) += 1;
            }
        }
        self.latency_ms.observe(resp.latency_ms);
    }

    fn merge(&mut self, other: &FamilyMetrics) {
        self.requests_completed += other.requests_completed;
        self.halted_early += other.halted_early;
        self.steps_executed += other.steps_executed;
        self.steps_saved += other.steps_saved;
        self.latency_ms.merge(&other.latency_ms);
        for (reason, n) in &other.halted_by {
            *self.halted_by.entry(reason.clone()).or_insert(0) += n;
        }
        self.prediction_err_steps += other.prediction_err_steps;
        self.predictions += other.predictions;
        self.tokens_frozen += other.tokens_frozen;
        self.frozen_token_steps += other.frozen_token_steps;
        self.token_steps_total += other.token_steps_total;
        self.token_steps_saved += other.token_steps_saved;
    }

    /// Fraction of this lane's token-steps spent on already-frozen
    /// positions (0.0 until a completion froze something).
    pub fn frozen_step_fraction(&self) -> f64 {
        if self.token_steps_total == 0 {
            0.0
        } else {
            self.frozen_token_steps as f64 / self.token_steps_total as f64
        }
    }
}

/// Serving metrics for one worker shard (or the scheduler's admission
/// side); merged across the fleet for the `/metrics` snapshot.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub started_at: Instant,
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub halted_early: u64,
    /// denoiser steps actually executed (per-request accounting; aborted
    /// requests contribute the steps they burned before the abort)
    pub steps_executed: u64,
    /// steps the requests budgeted but never ran (saved by halting)
    pub steps_saved: u64,
    /// device calls (batched steps)
    pub device_calls: u64,
    /// admission rejections from the bounded queue (backpressure)
    pub rejected_overloaded: u64,
    /// admission rejections for unserveable requests (overlong prefix,
    /// family with no live worker, duplicate in-flight id)
    pub rejected_invalid: u64,
    /// requests cancelled while queued or running
    pub cancelled: u64,
    /// requests dropped because `deadline_ms` expired
    pub deadline_exceeded: u64,
    /// admission rejections because the predicted wall time exceeded
    /// the request's `deadline_ms` (predictor admission gate)
    pub rejected_infeasible: u64,
    /// total steps-to-halt predictions graded against an actual
    /// completion (feeds the `prediction_mae_steps` lanes)
    pub predictions_made: u64,
    /// progress frames evicted from slow subscribers' bounded
    /// per-connection buffers (drop-oldest flow control)
    pub progress_dropped: u64,
    /// completed drain→rebind→rejoin cycles on this shard
    pub rebinds: u64,
    /// in-flight requests exported back to the queue by rebind drains
    /// (all of them resumed elsewhere or answered typed — never lost)
    pub rebind_requests_drained: u64,
    /// in-flight requests re-admitted after a worker death (bounded by
    /// the scheduler's retry budget)
    pub requests_retried: u64,
    /// requests that burned their whole retry budget and failed over
    /// to the typed `unavailable`
    pub retries_exhausted: u64,
    /// low-priority queued requests shed on a brownout transition
    /// (answered with the typed `overloaded`)
    pub brownout_shed: u64,
    /// mostly-frozen slots handed to a smaller shard mid-generation
    pub slots_migrated: u64,
    /// slot-steps reclaimed on the source shard by those migrations
    /// (remaining-step estimate at the moment of hand-off)
    pub migration_reclaimed_slot_steps: u64,
    /// slot-occupancy gauges (workers refresh these every loop)
    pub slots_total: u64,
    pub slots_busy: u64,
    /// steps burned by requests still in flight (gauge; completed and
    /// aborted requests move their steps into `steps_executed`)
    pub steps_in_flight: u64,
    pub latency_ms: Histogram,
    /// queueing delay before the first denoise step
    pub queue_ms: Histogram,
    /// service latency split by admission class (high / normal / low)
    pub latency_by_priority: [Histogram; Priority::COUNT],
    /// early halts per policy reason (`entropy`, `patience`, ...);
    /// surfaced in the JSON snapshot as `halted_by_<reason>`
    pub halted_by: BTreeMap<String, u64>,
    /// completion/latency/halt accounting split per model family (keyed
    /// by `Family::name()`); only families that completed work appear
    pub per_family: BTreeMap<String, FamilyMetrics>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started_at: Instant::now(),
            requests_submitted: 0,
            requests_completed: 0,
            halted_early: 0,
            steps_executed: 0,
            steps_saved: 0,
            device_calls: 0,
            rejected_overloaded: 0,
            rejected_invalid: 0,
            cancelled: 0,
            deadline_exceeded: 0,
            rejected_infeasible: 0,
            predictions_made: 0,
            progress_dropped: 0,
            rebinds: 0,
            rebind_requests_drained: 0,
            requests_retried: 0,
            retries_exhausted: 0,
            brownout_shed: 0,
            slots_migrated: 0,
            migration_reclaimed_slot_steps: 0,
            slots_total: 0,
            slots_busy: 0,
            steps_in_flight: 0,
            latency_ms: Histogram::default(),
            queue_ms: Histogram::default(),
            latency_by_priority: [
                Histogram::default(),
                Histogram::default(),
                Histogram::default(),
            ],
            halted_by: BTreeMap::new(),
            per_family: BTreeMap::new(),
        }
    }
}

impl Metrics {
    /// Account device steps burned by a request that was aborted
    /// (cancelled / deadline-expired) before completing — they count in
    /// the global total AND the family's lane, so per-family steps
    /// always reconcile with the fleet total.
    pub fn record_aborted_steps(
        &mut self,
        family: impl Into<FamilyId>,
        steps: u64,
    ) {
        self.steps_executed += steps;
        self.per_family
            .entry(family.into().name().to_string())
            .or_default()
            .steps_executed += steps;
    }

    /// Grade one steps-to-halt prediction against the steps the
    /// request actually executed.  The absolute error accumulates in
    /// the family's lane; the snapshot surfaces it as
    /// `prediction_mae_steps_<fam>` plus a fleet-wide
    /// `prediction_mae_steps`.
    pub fn record_prediction(
        &mut self,
        family: impl Into<FamilyId>,
        predicted_steps: u64,
        actual_steps: u64,
    ) {
        self.predictions_made += 1;
        let lane = self
            .per_family
            .entry(family.into().name().to_string())
            .or_default();
        lane.prediction_err_steps +=
            predicted_steps.abs_diff(actual_steps) as f64;
        lane.predictions += 1;
    }

    /// Account one completion's token-level halting: how many positions
    /// its policy froze, the token-steps spent stepping already-frozen
    /// positions, the token-level budget saving those freezes
    /// represent, and the completion's total token-steps
    /// (`steps_executed × L`).  Workers call this once per completion
    /// that froze at least one position; the snapshot surfaces the
    /// lanes as `tokens_frozen_<fam>`, `token_steps_saved_<fam>` and
    /// `frozen_step_fraction_<fam>` plus fleet-wide aggregates.
    pub fn record_token_halting(
        &mut self,
        family: impl Into<FamilyId>,
        tokens_frozen: u64,
        frozen_token_steps: u64,
        token_steps_saved: u64,
        token_steps_total: u64,
    ) {
        let lane = self
            .per_family
            .entry(family.into().name().to_string())
            .or_default();
        lane.tokens_frozen += tokens_frozen;
        lane.frozen_token_steps += frozen_token_steps;
        lane.token_steps_saved += token_steps_saved;
        lane.token_steps_total += token_steps_total;
    }

    /// Account one early halt attributed to a policy reason.
    pub fn record_halt(&mut self, reason: &str) {
        self.halted_early += 1;
        *self.halted_by.entry(reason.to_string()).or_insert(0) += 1;
    }

    /// The single bookkeeping path for every answered request — preflight
    /// resolutions and worker completions alike — so the two can't drift
    /// in steps/latency/halt accounting.  `family` is the kernel that
    /// served (or, for admission-side resolutions, would have served)
    /// the request; it feeds the per-family lanes of the snapshot.
    pub fn record_completion(
        &mut self,
        resp: &GenResponse,
        prio: Priority,
        family: impl Into<FamilyId>,
    ) {
        let family = family.into();
        self.requests_completed += 1;
        self.steps_executed += resp.steps_executed as u64;
        self.steps_saved +=
            resp.steps_budget.saturating_sub(resp.steps_executed) as u64;
        if resp.halted_early {
            if let Some(reason) = &resp.halt_reason {
                self.record_halt(reason);
            }
        }
        self.latency_ms.observe(resp.latency_ms);
        self.queue_ms.observe(resp.queue_ms);
        self.latency_by_priority[prio.index()].observe(resp.latency_ms);
        self.per_family
            .entry(family.name().to_string())
            .or_default()
            .record(resp);
    }

    /// Fold another shard's metrics in (fleet snapshot).
    pub fn merge(&mut self, other: &Metrics) {
        if other.started_at < self.started_at {
            self.started_at = other.started_at;
        }
        self.requests_submitted += other.requests_submitted;
        self.requests_completed += other.requests_completed;
        self.halted_early += other.halted_early;
        self.steps_executed += other.steps_executed;
        self.steps_saved += other.steps_saved;
        self.device_calls += other.device_calls;
        self.rejected_overloaded += other.rejected_overloaded;
        self.rejected_invalid += other.rejected_invalid;
        self.cancelled += other.cancelled;
        self.deadline_exceeded += other.deadline_exceeded;
        self.rejected_infeasible += other.rejected_infeasible;
        self.predictions_made += other.predictions_made;
        self.progress_dropped += other.progress_dropped;
        self.rebinds += other.rebinds;
        self.rebind_requests_drained += other.rebind_requests_drained;
        self.requests_retried += other.requests_retried;
        self.retries_exhausted += other.retries_exhausted;
        self.brownout_shed += other.brownout_shed;
        self.slots_migrated += other.slots_migrated;
        self.migration_reclaimed_slot_steps +=
            other.migration_reclaimed_slot_steps;
        self.slots_total += other.slots_total;
        self.slots_busy += other.slots_busy;
        self.steps_in_flight += other.steps_in_flight;
        self.latency_ms.merge(&other.latency_ms);
        self.queue_ms.merge(&other.queue_ms);
        for (h, o) in self
            .latency_by_priority
            .iter_mut()
            .zip(&other.latency_by_priority)
        {
            h.merge(o);
        }
        for (reason, n) in &other.halted_by {
            *self.halted_by.entry(reason.clone()).or_insert(0) += n;
        }
        for (fam, fm) in &other.per_family {
            self.per_family.entry(fam.clone()).or_default().merge(fm);
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        let el = self.started_at.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.requests_completed as f64 / el
        }
    }

    /// Fraction of budgeted steps avoided by early halting.
    pub fn step_saving_ratio(&self) -> f64 {
        let total = self.steps_executed + self.steps_saved;
        if total == 0 {
            0.0
        } else {
            self.steps_saved as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let base = Json::obj(vec![
            ("requests_submitted", Json::num(self.requests_submitted as f64)),
            ("requests_completed", Json::num(self.requests_completed as f64)),
            ("halted_early", Json::num(self.halted_early as f64)),
            ("steps_executed", Json::num(self.steps_executed as f64)),
            ("steps_saved", Json::num(self.steps_saved as f64)),
            ("step_saving_ratio", Json::num(self.step_saving_ratio())),
            ("device_calls", Json::num(self.device_calls as f64)),
            (
                "rejected_overloaded",
                Json::num(self.rejected_overloaded as f64),
            ),
            ("rejected_invalid", Json::num(self.rejected_invalid as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("deadline_exceeded", Json::num(self.deadline_exceeded as f64)),
            (
                "rejected_infeasible",
                Json::num(self.rejected_infeasible as f64),
            ),
            ("predictions_made", Json::num(self.predictions_made as f64)),
            ("slots_total", Json::num(self.slots_total as f64)),
            ("slots_busy", Json::num(self.slots_busy as f64)),
            ("steps_in_flight", Json::num(self.steps_in_flight as f64)),
            ("latency_mean_ms", Json::num(self.latency_ms.mean())),
            ("latency_p50_ms", Json::num(self.latency_ms.quantile(0.5))),
            ("latency_p95_ms", Json::num(self.latency_ms.quantile(0.95))),
            ("queue_mean_ms", Json::num(self.queue_ms.mean())),
            ("queue_p95_ms", Json::num(self.queue_ms.quantile(0.95))),
            ("throughput_rps", Json::num(self.throughput_rps())),
        ]);
        let mut m = base.into_obj();
        // elastic-fleet counters ride only once the feature fired, so
        // pre-elastic snapshots keep their exact key set
        if self.progress_dropped > 0 {
            m.insert(
                "progress_dropped".to_string(),
                Json::num(self.progress_dropped as f64),
            );
        }
        if self.rebinds > 0 {
            m.insert("rebinds".to_string(), Json::num(self.rebinds as f64));
            m.insert(
                "rebind_requests_drained".to_string(),
                Json::num(self.rebind_requests_drained as f64),
            );
        }
        if self.slots_migrated > 0 {
            m.insert(
                "slots_migrated".to_string(),
                Json::num(self.slots_migrated as f64),
            );
            m.insert(
                "migration_reclaimed_slot_steps".to_string(),
                Json::num(self.migration_reclaimed_slot_steps as f64),
            );
        }
        // chaos-hardening counters ride only once the feature fired,
        // same contract as the elastic lanes above
        if self.requests_retried > 0 {
            m.insert(
                "requests_retried".to_string(),
                Json::num(self.requests_retried as f64),
            );
        }
        if self.retries_exhausted > 0 {
            m.insert(
                "retries_exhausted".to_string(),
                Json::num(self.retries_exhausted as f64),
            );
        }
        if self.brownout_shed > 0 {
            m.insert(
                "brownout_shed".to_string(),
                Json::num(self.brownout_shed as f64),
            );
        }
        for prio in Priority::ALL {
            let h = &self.latency_by_priority[prio.index()];
            if h.count() > 0 {
                let name = prio.name();
                m.insert(
                    format!("latency_p50_ms_{name}"),
                    Json::num(h.quantile(0.5)),
                );
                m.insert(
                    format!("latency_p95_ms_{name}"),
                    Json::num(h.quantile(0.95)),
                );
            }
        }
        for (reason, n) in &self.halted_by {
            m.insert(format!("halted_by_{reason}"), Json::num(*n as f64));
        }
        for (fam, fm) in &self.per_family {
            m.insert(
                format!("requests_completed_{fam}"),
                Json::num(fm.requests_completed as f64),
            );
            m.insert(
                format!("halted_early_{fam}"),
                Json::num(fm.halted_early as f64),
            );
            m.insert(
                format!("steps_executed_{fam}"),
                Json::num(fm.steps_executed as f64),
            );
            m.insert(
                format!("steps_saved_{fam}"),
                Json::num(fm.steps_saved as f64),
            );
            if fm.latency_ms.count() > 0 {
                m.insert(
                    format!("latency_p50_ms_{fam}"),
                    Json::num(fm.latency_ms.quantile(0.5)),
                );
                m.insert(
                    format!("latency_p95_ms_{fam}"),
                    Json::num(fm.latency_ms.quantile(0.95)),
                );
            }
            for (reason, n) in &fm.halted_by {
                m.insert(
                    format!("halted_by_{reason}_{fam}"),
                    Json::num(*n as f64),
                );
            }
            if fm.predictions > 0 {
                m.insert(
                    format!("prediction_mae_steps_{fam}"),
                    Json::num(fm.prediction_err_steps / fm.predictions as f64),
                );
            }
            // token-halting lanes ride only for families that actually
            // froze positions, so pre-token-halting snapshots (and
            // fleets with the feature unused) keep their exact key set
            if fm.token_steps_total > 0 {
                m.insert(
                    format!("tokens_frozen_{fam}"),
                    Json::num(fm.tokens_frozen as f64),
                );
                m.insert(
                    format!("token_steps_saved_{fam}"),
                    Json::num(fm.token_steps_saved as f64),
                );
                m.insert(
                    format!("frozen_step_fraction_{fam}"),
                    Json::num(fm.frozen_step_fraction()),
                );
            }
        }
        let (err, n) = self.per_family.values().fold((0.0, 0u64), |(e, n), fm| {
            (e + fm.prediction_err_steps, n + fm.predictions)
        });
        if n > 0 {
            m.insert("prediction_mae_steps".to_string(), Json::num(err / n as f64));
        }
        let (tf, fts, tss, tst) = self.per_family.values().fold(
            (0u64, 0u64, 0u64, 0u64),
            |(tf, fts, tss, tst), fm| {
                (
                    tf + fm.tokens_frozen,
                    fts + fm.frozen_token_steps,
                    tss + fm.token_steps_saved,
                    tst + fm.token_steps_total,
                )
            },
        );
        if tst > 0 {
            m.insert("tokens_frozen".to_string(), Json::num(tf as f64));
            m.insert("token_steps_saved".to_string(), Json::num(tss as f64));
            m.insert(
                "frozen_step_fraction".to_string(),
                Json::num(fts as f64 / tst as f64),
            );
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenRequest;
    use crate::sampler::Family;

    #[test]
    fn histogram_mean_and_quantiles() {
        let mut h = Histogram::default();
        for v in [1.0, 2.0, 4.0, 8.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 3.75).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 2.0 && h.quantile(0.5) <= 4.0);
        assert!(h.quantile(1.0) >= 8.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.9), 0.0);
    }

    #[test]
    fn histogram_merge_sums_counts_and_moments() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        for v in [1.0, 2.0] {
            a.observe(v);
        }
        for v in [4.0, 64.0] {
            b.observe(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 17.75).abs() < 1e-9);
        assert_eq!(a.max(), 64.0);
    }

    #[test]
    fn saving_ratio() {
        let mut m = Metrics::default();
        m.steps_executed = 600;
        m.steps_saved = 400;
        assert!((m.step_saving_ratio() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn metrics_json_has_headline_fields() {
        let m = Metrics::default();
        let j = m.to_json();
        assert!(j.get("step_saving_ratio").is_some());
        assert!(j.get("latency_p95_ms").is_some());
        // the serving-stack counters are always present, even at zero
        for key in [
            "rejected_overloaded",
            "rejected_invalid",
            "cancelled",
            "deadline_exceeded",
        ] {
            assert_eq!(
                j.get(key).and_then(|v| v.as_f64()),
                Some(0.0),
                "missing {key}"
            );
        }
    }

    #[test]
    fn per_reason_halt_counters_flattened_into_json() {
        let mut m = Metrics::default();
        m.record_halt("entropy");
        m.record_halt("entropy");
        m.record_halt("kl");
        assert_eq!(m.halted_early, 3);
        let j = m.to_json();
        assert_eq!(
            j.get("halted_by_entropy").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(j.get("halted_by_kl").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("halted_by_patience").is_none());
    }

    #[test]
    fn record_completion_unifies_preflight_and_worker_paths() {
        use crate::coordinator::request::GenResponse;
        use crate::halting::parse_policy;

        let mut m = Metrics::default();
        // preflight path: fixed:0 resolves with zero executed steps
        let mut req = GenRequest::new(1, 10);
        req.policy = parse_policy("fixed:0").unwrap();
        let pre = GenResponse::preflight(&req, "fixed");
        m.record_completion(&pre, req.priority, Family::Ddlm);
        // worker path: early halt at step 4 of 10
        let worker = GenResponse {
            id: 2,
            tokens: vec![0; 8],
            steps_executed: 4,
            steps_budget: 10,
            halted_early: true,
            halt_reason: Some("fixed".to_string()),
            latency_ms: 12.0,
            queue_ms: 3.0,
            family: Some(Family::Ddlm.into()),
            final_stats: Default::default(),
            predicted_steps_remaining: None,
            predicted_total_steps: None,
        };
        m.record_completion(&worker, Priority::High, Family::Ddlm);
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.steps_executed, 4);
        assert_eq!(m.steps_saved, 16);
        assert_eq!(m.halted_by.get("fixed"), Some(&2));
        // both paths observe latency + queue histograms
        assert_eq!(m.latency_ms.count(), 2);
        assert_eq!(m.queue_ms.count(), 2);
        assert_eq!(m.latency_by_priority[Priority::High.index()].count(), 1);
        assert_eq!(m.latency_by_priority[Priority::Normal.index()].count(), 1);
        // ...and both feed the same per-family lane
        let lane = m.per_family.get("ddlm").unwrap();
        assert_eq!(lane.requests_completed, 2);
        assert_eq!(lane.steps_executed, 4);
        assert_eq!(lane.steps_saved, 16);
        assert_eq!(lane.halted_by.get("fixed"), Some(&2));
    }

    #[test]
    fn per_family_lanes_split_completions_and_flatten_into_json() {
        let mut m = Metrics::default();
        let resp = |id: u64, fam: Family| GenResponse {
            id,
            tokens: vec![],
            steps_executed: 5,
            steps_budget: 10,
            halted_early: true,
            halt_reason: Some("entropy".to_string()),
            latency_ms: 8.0,
            queue_ms: 1.0,
            family: Some(fam.into()),
            final_stats: Default::default(),
            predicted_steps_remaining: None,
            predicted_total_steps: None,
        };
        m.record_completion(&resp(1, Family::Ddlm), Priority::Normal, Family::Ddlm);
        m.record_completion(&resp(2, Family::Ddlm), Priority::Normal, Family::Ddlm);
        m.record_completion(&resp(3, Family::Ssd), Priority::Normal, Family::Ssd);
        let j = m.to_json();
        let get = |k: &str| j.get(k).and_then(|v| v.as_f64());
        assert_eq!(get("requests_completed_ddlm"), Some(2.0));
        assert_eq!(get("requests_completed_ssd"), Some(1.0));
        assert_eq!(get("halted_by_entropy_ddlm"), Some(2.0));
        assert_eq!(get("halted_by_entropy_ssd"), Some(1.0));
        assert!(get("latency_p95_ms_ddlm").is_some());
        // families that served nothing stay out of the snapshot
        assert!(j.get("requests_completed_plaid").is_none());
    }

    #[test]
    fn aborted_steps_count_in_global_and_family_lane() {
        let mut m = Metrics::default();
        m.record_aborted_steps(Family::Ssd, 50);
        assert_eq!(m.steps_executed, 50);
        let lane = m.per_family.get("ssd").unwrap();
        assert_eq!(lane.steps_executed, 50);
        // an abort is not a completion
        assert_eq!(m.requests_completed, 0);
        assert_eq!(lane.requests_completed, 0);
        assert_eq!(lane.latency_ms.count(), 0);
    }

    #[test]
    fn merge_folds_per_family_lanes() {
        let mk = |fam: Family, n: u64| {
            let mut m = Metrics::default();
            for id in 0..n {
                let r = GenResponse {
                    id,
                    tokens: vec![],
                    steps_executed: 3,
                    steps_budget: 3,
                    halted_early: false,
                    halt_reason: None,
                    latency_ms: 4.0,
                    queue_ms: 0.5,
                    family: Some(fam.into()),
                    final_stats: Default::default(),
                    predicted_steps_remaining: None,
                    predicted_total_steps: None,
                };
                m.record_completion(&r, Priority::Normal, fam);
            }
            m
        };
        let mut a = mk(Family::Ddlm, 2);
        let b = mk(Family::Ddlm, 1);
        let c = mk(Family::Plaid, 3);
        a.merge(&b);
        a.merge(&c);
        assert_eq!(a.per_family.get("ddlm").unwrap().requests_completed, 3);
        assert_eq!(a.per_family.get("plaid").unwrap().requests_completed, 3);
        assert_eq!(a.per_family.get("ddlm").unwrap().latency_ms.count(), 3);
        assert_eq!(a.requests_completed, 6);
    }

    #[test]
    fn merge_folds_counters_histograms_and_reasons() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.record_halt("entropy");
        b.record_halt("entropy");
        b.record_halt("kl");
        a.requests_completed = 3;
        b.requests_completed = 4;
        b.rejected_overloaded = 2;
        b.cancelled = 1;
        b.deadline_exceeded = 5;
        a.slots_total = 1;
        b.slots_total = 8;
        b.slots_busy = 6;
        a.latency_ms.observe(2.0);
        b.latency_ms.observe(8.0);
        a.merge(&b);
        assert_eq!(a.requests_completed, 7);
        assert_eq!(a.rejected_overloaded, 2);
        assert_eq!(a.cancelled, 1);
        assert_eq!(a.deadline_exceeded, 5);
        assert_eq!(a.slots_total, 9);
        assert_eq!(a.slots_busy, 6);
        assert_eq!(a.latency_ms.count(), 2);
        assert_eq!(a.halted_by.get("entropy"), Some(&2));
        assert_eq!(a.halted_by.get("kl"), Some(&1));
    }

    #[test]
    fn prediction_mae_lanes_flatten_into_json() {
        let mut m = Metrics::default();
        // no predictions yet → counter present at zero, no MAE keys
        let j = m.to_json();
        assert_eq!(
            j.get("predictions_made").and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert_eq!(
            j.get("rejected_infeasible").and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert!(j.get("prediction_mae_steps").is_none());
        m.record_prediction(Family::Ddlm, 100, 90);
        m.record_prediction(Family::Ddlm, 100, 110);
        m.record_prediction(Family::Ssd, 50, 50);
        let j = m.to_json();
        let get = |k: &str| j.get(k).and_then(|v| v.as_f64());
        assert_eq!(get("predictions_made"), Some(3.0));
        assert_eq!(get("prediction_mae_steps_ddlm"), Some(10.0));
        assert_eq!(get("prediction_mae_steps_ssd"), Some(0.0));
        let fleet = get("prediction_mae_steps").unwrap();
        assert!((fleet - 20.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn merge_folds_prediction_lanes() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.record_prediction(Family::Ddlm, 10, 14);
        b.record_prediction(Family::Ddlm, 10, 6);
        b.rejected_infeasible = 2;
        a.merge(&b);
        assert_eq!(a.predictions_made, 2);
        assert_eq!(a.rejected_infeasible, 2);
        let lane = a.per_family.get("ddlm").unwrap();
        assert_eq!(lane.predictions, 2);
        assert!((lane.prediction_err_steps - 8.0).abs() < 1e-9);
    }

    #[test]
    fn token_halting_lanes_flatten_and_stay_absent_when_unused() {
        let mut m = Metrics::default();
        // feature unused → no token keys at all (snapshot key set is
        // identical to a pre-token-halting server's)
        let j = m.to_json();
        assert!(j.get("tokens_frozen").is_none());
        assert!(j.get("frozen_step_fraction").is_none());
        assert!(j.get("tokens_frozen_ddlm").is_none());
        // one ddlm completion: 12 frozen positions, 256 of 640
        // token-steps spent on pinned positions, 300 budget-steps saved
        m.record_token_halting(Family::Ddlm, 12, 256, 300, 640);
        let j = m.to_json();
        let get = |k: &str| j.get(k).and_then(|v| v.as_f64());
        assert_eq!(get("tokens_frozen_ddlm"), Some(12.0));
        assert_eq!(get("token_steps_saved_ddlm"), Some(300.0));
        assert!((get("frozen_step_fraction_ddlm").unwrap() - 0.4).abs() < 1e-9);
        // fleet aggregates mirror the single lane
        assert_eq!(get("tokens_frozen"), Some(12.0));
        assert_eq!(get("token_steps_saved"), Some(300.0));
        assert!((get("frozen_step_fraction").unwrap() - 0.4).abs() < 1e-9);
        // other families stay out
        assert!(j.get("tokens_frozen_ssd").is_none());
    }

    #[test]
    fn merge_folds_token_halting_lanes() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.record_token_halting(Family::Ddlm, 4, 100, 50, 400);
        b.record_token_halting(Family::Ddlm, 6, 100, 70, 400);
        b.record_token_halting(Family::Ssd, 2, 10, 5, 100);
        a.merge(&b);
        let lane = a.per_family.get("ddlm").unwrap();
        assert_eq!(lane.tokens_frozen, 10);
        assert_eq!(lane.frozen_token_steps, 200);
        assert_eq!(lane.token_steps_saved, 120);
        assert_eq!(lane.token_steps_total, 800);
        assert!((lane.frozen_step_fraction() - 0.25).abs() < 1e-9);
        assert_eq!(a.per_family.get("ssd").unwrap().tokens_frozen, 2);
    }

    #[test]
    fn elastic_counters_flatten_and_stay_absent_when_unused() {
        let mut m = Metrics::default();
        // feature unused → pre-elastic snapshot key set, untouched
        let j = m.to_json();
        assert!(j.get("progress_dropped").is_none());
        assert!(j.get("rebinds").is_none());
        assert!(j.get("slots_migrated").is_none());
        m.progress_dropped = 7;
        m.rebinds = 2;
        m.rebind_requests_drained = 5;
        m.slots_migrated = 3;
        m.migration_reclaimed_slot_steps = 120;
        let j = m.to_json();
        let get = |k: &str| j.get(k).and_then(|v| v.as_f64());
        assert_eq!(get("progress_dropped"), Some(7.0));
        assert_eq!(get("rebinds"), Some(2.0));
        assert_eq!(get("rebind_requests_drained"), Some(5.0));
        assert_eq!(get("slots_migrated"), Some(3.0));
        assert_eq!(get("migration_reclaimed_slot_steps"), Some(120.0));
    }

    #[test]
    fn merge_folds_elastic_counters() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.rebinds = 1;
        b.rebinds = 2;
        b.rebind_requests_drained = 4;
        b.progress_dropped = 9;
        b.slots_migrated = 1;
        b.migration_reclaimed_slot_steps = 40;
        a.merge(&b);
        assert_eq!(a.rebinds, 3);
        assert_eq!(a.rebind_requests_drained, 4);
        assert_eq!(a.progress_dropped, 9);
        assert_eq!(a.slots_migrated, 1);
        assert_eq!(a.migration_reclaimed_slot_steps, 40);
    }

    #[test]
    fn per_priority_latency_appears_only_when_observed() {
        let mut m = Metrics::default();
        let j = m.to_json();
        assert!(j.get("latency_p50_ms_high").is_none());
        m.latency_by_priority[Priority::High.index()].observe(4.0);
        let j = m.to_json();
        assert!(j.get("latency_p50_ms_high").is_some());
        assert!(j.get("latency_p50_ms_low").is_none());
    }
}
