//! Declared registry of every key the metrics snapshot can emit.
//!
//! The snapshot is assembled in three places — [`super::Metrics::to_json`]
//! (counters, histograms, per-family lanes), `engine::EngineHandle::metrics`
//! (fleet gauges, cache counters, the per-worker breakdown) and
//! `predictor::Estimator::snapshot_json` (per-family estimator state).
//! Every string key those sites construct MUST be declared here, either
//! verbatim in [`SNAPSHOT_KEYS`] or by one of the dynamic-lane prefixes
//! in [`SNAPSHOT_PREFIXES`] (`latency_p50_ms_<fam>`, `halted_by_<reason>`,
//! ...).  `repro analyze` (the `metrics-registry` check) walks those
//! three files and fails on any emission this registry does not cover,
//! and `scripts/bench_schema.txt` must stay a subset of the declared
//! surface — so a key can no longer slip into the wire snapshot (or the
//! bench schema) without being registered, reviewed and documented.

/// Fixed snapshot keys, in emission-site order: the `Metrics::to_json`
/// base object, its conditional (feature-fired) keys, the engine's
/// fleet-level gauges and nested objects, and the estimator snapshot's
/// per-family fields.
pub const SNAPSHOT_KEYS: &[&str] = &[
    // Metrics::to_json base object
    "requests_submitted",
    "requests_completed",
    "halted_early",
    "steps_executed",
    "steps_saved",
    "step_saving_ratio",
    "device_calls",
    "rejected_overloaded",
    "rejected_invalid",
    "cancelled",
    "deadline_exceeded",
    "rejected_infeasible",
    "predictions_made",
    "slots_total",
    "slots_busy",
    "steps_in_flight",
    "latency_mean_ms",
    "latency_p50_ms",
    "latency_p95_ms",
    "queue_mean_ms",
    "queue_p95_ms",
    "throughput_rps",
    // conditional (absent until the feature fires)
    "progress_dropped",
    "rebinds",
    "rebind_requests_drained",
    "slots_migrated",
    "migration_reclaimed_slot_steps",
    "prediction_mae_steps",
    "tokens_frozen",
    "token_steps_saved",
    "frozen_step_fraction",
    "lock_poisoned",
    // chaos-hardening lanes (absent until the feature fires / is
    // configured): worker-death retries, brownout shedding, the
    // fleet-health verdict, and the write-ahead journal counters
    "requests_retried",
    "retries_exhausted",
    "brownout_shed",
    "fleet_health",
    "journal_records",
    "journal_replayed",
    "journal_truncated_records",
    "journal_bytes",
    "journal_write_failures",
    // engine fleet gauges + per-worker breakdown + nested objects
    "worker",
    "family",
    "queue_depth",
    "running_requests",
    "workers",
    "artifact_cache_hits",
    "artifact_cache_misses",
    "artifact_cache_evictions",
    "artifact_cache_bytes",
    "t_max",
    "t_min",
    "families",
    "predictor",
    // estimator snapshot per-family fields
    "observations",
    "buckets",
    "slope_buckets",
    "ema_total_steps",
    "step_latency_ms",
];

/// Dynamic-lane prefixes: keys suffixed by a family name, priority
/// class or halt reason.  An emitted `format!("<prefix>{suffix}")` key
/// is declared iff its literal prefix is listed here.
pub const SNAPSHOT_PREFIXES: &[&str] = &[
    "latency_p50_ms_",
    "latency_p95_ms_",
    "halted_by_",
    "requests_completed_",
    "halted_early_",
    "steps_executed_",
    "steps_saved_",
    "prediction_mae_steps_",
    "tokens_frozen_",
    "token_steps_saved_",
    "frozen_step_fraction_",
    "faults_injected_",
];

/// Keys `scripts/bench_schema.txt` may use that are bench-harness
/// outputs rather than snapshot fields (`BENCH_serving.json` rows).
/// Schema keys must come from here, [`SNAPSHOT_KEYS`] or a
/// [`SNAPSHOT_PREFIXES`] match.
pub const BENCH_KEYS: &[&str] = &[
    "bench",
    "criterion",
    "req_per_s",
    "steps_per_s",
    "host_bytes_per_step",
    "stream_overhead_pct",
    "elastic",
    "rebind_ms",
    "requests_dropped",
    "goodput_before",
    "goodput_during",
    "goodput_after",
    "reclaimed_slot_steps",
    "recovery",
    "recovery_ms",
    "requests_replayed",
    "requests_lost",
];

/// True when `key` is a declared snapshot key (verbatim or via a
/// dynamic-lane prefix).
pub fn is_declared(key: &str) -> bool {
    SNAPSHOT_KEYS.contains(&key)
        || SNAPSHOT_PREFIXES.iter().any(|p| key.starts_with(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_no_duplicates() {
        for (i, k) in SNAPSHOT_KEYS.iter().enumerate() {
            assert!(
                !SNAPSHOT_KEYS[i + 1..].contains(k),
                "duplicate snapshot key {k:?}"
            );
        }
        for (i, p) in SNAPSHOT_PREFIXES.iter().enumerate() {
            assert!(
                !SNAPSHOT_PREFIXES[i + 1..].contains(p),
                "duplicate prefix {p:?}"
            );
        }
    }

    #[test]
    fn prefixes_end_with_a_separator() {
        for p in SNAPSHOT_PREFIXES {
            assert!(p.ends_with('_'), "prefix {p:?} must end with '_'");
        }
    }

    /// The bench schema is a declared subset: every key the bench
    /// validator greps for must be registered here (the same rule
    /// `repro analyze` enforces statically).
    #[test]
    fn bench_schema_is_a_subset_of_the_registry() {
        let path = format!(
            "{}/scripts/bench_schema.txt",
            env!("CARGO_MANIFEST_DIR")
        );
        let schema = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {path}: {e}"));
        for key in schema
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
        {
            assert!(
                BENCH_KEYS.contains(&key) || is_declared(key),
                "bench_schema.txt key {key:?} is not declared in \
                 metrics::keys"
            );
        }
    }

    #[test]
    fn emitted_base_keys_are_declared() {
        // spot-check the always-present base object against the registry
        let m = super::super::Metrics::default();
        if let crate::util::json::Json::Obj(obj) = m.to_json() {
            for k in obj.keys() {
                assert!(is_declared(k), "emitted key {k:?} undeclared");
            }
        } else {
            panic!("snapshot must be an object");
        }
    }
}
