//! First-class v1 client for the serving API — the ONE client
//! implementation shared by `repro client`, the examples, the serving
//! bench and the integration tests (instead of ad-hoc JSON in each).
//!
//! The client speaks the v1 envelope protocol ([`super::envelope`])
//! over one blocking TCP connection: [`Client::submit`] sends a
//! request frame, [`Client::next_event`] pulls the next server frame
//! (buffered events first), and the typed verbs
//! [`Client::halt`] / [`Client::cancel`] / [`Client::metrics`] /
//! [`Client::rebind`] can be
//! issued between `next_event` calls *while a generation streams* —
//! their acks are matched out of the interleaved frame stream and
//! everything else is buffered for the next `next_event` call.  [`Client::generate`] /
//! [`Client::generate_with`] are the blocking conveniences most
//! callers want.
//!
//! [`Client::roundtrip`] remains as the legacy escape hatch (send one
//! bare JSON line, read one line) for compatibility tests against the
//! pre-envelope protocol; do not mix it with in-flight v1 streams.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::envelope::{Command, Event};
use super::request::{GenRequest, GenResponse, ProgressEvent};
use crate::util::json::Json;

/// Typed server-side failure surfaced by [`Client::generate`] /
/// [`Client::generate_with`]: the wire error code, the optional
/// human-readable detail and — while the fleet is degraded or browned
/// out — the server's suggested backoff.  It rides inside the
/// `anyhow` error, so callers can `downcast_ref::<RemoteError>()` for
/// the structured fields while existing string matching on
/// `"server error: <code>"` keeps working.
#[derive(Clone, Debug)]
pub struct RemoteError {
    pub code: String,
    pub message: Option<String>,
    /// backoff hint from the server's `retry_after_ms` error field
    pub retry_after_ms: Option<u64>,
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.message {
            Some(m) => write!(f, "server error: {} ({m})", self.code)?,
            None => write!(f, "server error: {}", self.code)?,
        }
        if let Some(ms) = self.retry_after_ms {
            write!(f, "; retry in {ms} ms")?;
        }
        Ok(())
    }
}

impl std::error::Error for RemoteError {}

/// Typed reply to [`Client::cancel`].
#[derive(Clone, Debug)]
pub struct CancelAck {
    /// true when the cancel reached a live (queued or running) request
    pub cancelled: bool,
    /// `"queued" | "running" | "not_found"`
    pub state: String,
}

/// Typed reply to [`Client::halt`].
#[derive(Clone, Debug)]
pub struct HaltAck {
    /// true when the halt reached a live request (its normal completion
    /// — `halt_reason:"client"` — is delivered to the submitter's
    /// stream, which may be this same connection)
    pub found: bool,
    /// `"queued" | "running" | "not_found"`
    pub state: String,
}

/// Typed reply to [`Client::rebind`].
#[derive(Clone, Debug)]
pub struct RebindAck {
    /// true when the worker drained, rebuilt and rejoined under the
    /// new binding; false on a typed refusal (`unknown_worker`,
    /// `rebind_in_flight`, unknown family, ...) or a failure the
    /// worker reverted from
    pub ok: bool,
    /// refusal / failure detail when `ok` is false
    pub message: Option<String>,
    /// family the worker serves after the rebind
    pub family: Option<String>,
    /// batch shard the worker runs after the rebind
    pub batch: Option<usize>,
    /// in-flight slots drained back to the queue (resumed elsewhere or
    /// on the rebuilt worker — never dropped)
    pub drained: Option<usize>,
    /// wall-clock drain→rebuild→rejoin time in milliseconds
    pub rebind_ms: Option<f64>,
}

/// Blocking v1 serving-API client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// frames read while waiting for a specific ack, replayed by
    /// [`Client::next_event`] in arrival order
    pending: VecDeque<Event>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            pending: VecDeque::new(),
        })
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    fn read_event(&mut self) -> Result<Event> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed by server");
            }
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line.trim_end())
                .map_err(|e| anyhow::anyhow!("frame parse: {e}"))?;
            return Event::from_json(&j);
        }
    }

    /// Next server frame: buffered events first, then the wire.
    pub fn next_event(&mut self) -> Result<Event> {
        match self.pending.pop_front() {
            Some(ev) => Ok(ev),
            None => self.read_event(),
        }
    }

    /// Send a submit frame; events for it arrive through
    /// [`Self::next_event`] (progress frames if `req.progress_every`
    /// is set, then exactly one `done` or `error`).
    pub fn submit(&mut self, req: &GenRequest) -> Result<()> {
        // cheap clone-free framing: reuse the request's JSON and stamp
        // the envelope fields on
        let mut m = req.to_json().into_obj();
        m.insert("v".to_string(), Json::uint(1));
        m.insert("type".to_string(), Json::str("submit"));
        self.send_line(&Json::Obj(m).encode())
    }

    /// Blocking generate: submit, drain this request's events, return
    /// its final response.  Progress events (if subscribed) are
    /// discarded — use [`Self::generate_with`] to observe them.
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        self.generate_with(req, |_| {})
    }

    /// Blocking generate with a progress callback: every streamed
    /// [`ProgressEvent`] for this request is handed to `on_progress`
    /// as it arrives; frames for other in-flight requests are buffered
    /// for [`Self::next_event`].
    pub fn generate_with(
        &mut self,
        req: &GenRequest,
        mut on_progress: impl FnMut(&ProgressEvent),
    ) -> Result<GenResponse> {
        let id = req.id;
        self.submit(req)?;
        loop {
            match self.next_event()? {
                Event::Progress(ev) if ev.id == id => on_progress(&ev),
                Event::Done(resp) if resp.id == id => return Ok(resp),
                Event::Error { id: eid, code, message, retry_after_ms }
                    if eid == Some(id) || eid.is_none() =>
                {
                    return Err(RemoteError {
                        code,
                        message,
                        retry_after_ms,
                    }
                    .into());
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Gracefully halt a request by id.  The halted request finishes
    /// with a NORMAL response carrying its current decode and
    /// `halt_reason:"client"`.
    ///
    /// To halt based on streamed completeness, drive the stream
    /// yourself ([`Self::submit`] + [`Self::next_event`]) and call
    /// this between events — `generate_with`'s callback cannot call
    /// back into the client (it borrows it for the whole call); see
    /// the streaming integration tests for the pattern.
    pub fn halt(&mut self, id: u64) -> Result<HaltAck> {
        self.send_line(&Command::Halt { id }.to_json().encode())?;
        loop {
            match self.read_event()? {
                Event::HaltAck { id: aid, found, state } if aid == id => {
                    return Ok(HaltAck { found, state });
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Cancel (abort) a queued or running request by id; the submitter
    /// receives a typed `cancelled` error.
    pub fn cancel(&mut self, id: u64) -> Result<CancelAck> {
        self.send_line(&Command::Cancel { id }.to_json().encode())?;
        loop {
            match self.read_event()? {
                Event::CancelAck { id: aid, cancelled, state }
                    if aid == id =>
                {
                    return Ok(CancelAck { cancelled, state });
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Admin: live-rebind worker `worker` — drain its in-flight slots
    /// back to the queue (resumable, zero dropped), rebuild its
    /// session under the new binding and rejoin.  `None` fields keep
    /// the worker's current value; `Some("")` for `checkpoint` drops
    /// to init params.  Blocks until the fleet answers the ack — on a
    /// loaded fleet this spans a full drain + checkpoint load.
    pub fn rebind(
        &mut self,
        worker: usize,
        family: Option<&str>,
        batch: Option<usize>,
        checkpoint: Option<&str>,
    ) -> Result<RebindAck> {
        let cmd = Command::Rebind {
            worker,
            family: family.map(str::to_string),
            batch,
            checkpoint: checkpoint.map(str::to_string),
        };
        self.send_line(&cmd.to_json().encode())?;
        loop {
            match self.read_event()? {
                Event::RebindAck {
                    worker: aw,
                    ok,
                    message,
                    family,
                    batch,
                    drained,
                    rebind_ms,
                } if aw == worker => {
                    return Ok(RebindAck {
                        ok,
                        message,
                        family,
                        batch,
                        drained,
                        rebind_ms,
                    });
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Merged fleet metrics snapshot (the unwrapped `data` object of
    /// the v1 metrics frame — same shape the legacy `{"cmd":"metrics"}`
    /// control returns).
    pub fn metrics(&mut self) -> Result<Json> {
        self.send_line(&Command::Metrics.to_json().encode())?;
        loop {
            match self.read_event()? {
                Event::Metrics(data) => return Ok(data),
                other => self.pending.push_back(other),
            }
        }
    }

    /// Legacy escape hatch: send one bare (pre-envelope) JSON line and
    /// read exactly one reply line.  For compatibility tests against
    /// the legacy one-shot protocol — do not interleave with in-flight
    /// v1 streams on the same connection.
    pub fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        self.send_line(&msg.encode())?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("connection closed by server");
        }
        Json::parse(line.trim_end())
            .map_err(|e| anyhow::anyhow!("response parse: {e}"))
    }
}
