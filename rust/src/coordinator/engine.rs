//! The serving engine: a continuous batcher with early-exit slot recycling.
//!
//! One engine thread owns the (non-`Send`) PJRT runtime and a batched
//! generation `Session`.  Requests arrive over a channel; the scheduler
//! admits them into free batch slots immediately — *including slots freed
//! mid-schedule by another request's early exit* (the per-slot timestep
//! design in the step artifacts makes mixed-phase batches legal).  This is
//! the serving-side payoff of the paper: halting doesn't just cut one
//! request's latency, it raises fleet throughput because the freed slot
//! starts the next request `saved_steps` earlier.
//!
//! Scheduling policy: FIFO admission; a device step runs whenever at least
//! one slot is active; responses are emitted the moment a slot's halting
//! policy fires or its schedule exhausts.  Each running slot owns a boxed
//! [`crate::halting::HaltPolicy`] cloned from its request, so arbitrary
//! policy mixes (including combinators) coexist in one batch, and every
//! early halt is attributed to the primitive reason that fired.

use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse};
use crate::halting::{BoxedPolicy, Decision, HaltPolicy, StepStats};
use crate::log_info;
use crate::models::store::ParamStore;
use crate::runtime::Runtime;
use crate::sampler::{Family, Session};
use crate::util::json::Json;

pub enum EngineMsg {
    Submit(GenRequest, mpsc::Sender<GenResponse>),
    /// fetch a metrics snapshot
    Metrics(mpsc::Sender<Json>),
    Shutdown,
}

#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
}

impl EngineHandle {
    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenResponse> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(EngineMsg::Submit(req, tx));
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        Ok(self.submit(req).recv()?)
    }

    pub fn metrics(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(EngineMsg::Metrics(tx));
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
    }
}

pub struct EngineConfig {
    pub artifact_dir: String,
    pub family: Family,
    pub batch: usize,
    /// trained checkpoint (PBIN); falls back to init params when None
    pub checkpoint: Option<String>,
    pub t_max: f32,
    pub t_min: f32,
}

impl EngineConfig {
    pub fn new(artifact_dir: &str, family: Family) -> EngineConfig {
        EngineConfig {
            artifact_dir: artifact_dir.to_string(),
            family,
            batch: 8,
            checkpoint: None,
            t_max: 10.0,
            t_min: 0.05,
        }
    }
}

struct Pending {
    req: GenRequest,
    reply: mpsc::Sender<GenResponse>,
    submitted: Instant,
}

struct Running {
    req: GenRequest,
    reply: mpsc::Sender<GenResponse>,
    /// this slot's live policy (cloned from the request and reset on
    /// admission; the request keeps the pristine copy for its spec)
    policy: BoxedPolicy,
    submitted: Instant,
    started: Instant,
}

/// Spawn the engine thread; returns a cloneable handle plus the join
/// handle (joining after `shutdown()` surfaces engine errors).
pub fn start(cfg: EngineConfig) -> (EngineHandle, JoinHandle<Result<()>>) {
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let handle = EngineHandle { tx };
    let join = std::thread::spawn(move || run_engine(cfg, rx));
    (handle, join)
}

fn run_engine(cfg: EngineConfig, rx: mpsc::Receiver<EngineMsg>) -> Result<()> {
    let rt = Runtime::new(&cfg.artifact_dir)?;
    let m = rt.manifest.model.clone();
    let store = match &cfg.checkpoint {
        Some(path) => ParamStore::load(path, cfg.family.name())?,
        None => ParamStore::load_init(&cfg.artifact_dir, cfg.family.name())?,
    };
    // artifacts are compiled for fixed batch sizes; resolve the nearest
    // available one (>= requested, else the largest)
    let batch = rt.manifest.resolve_step_batch(
        cfg.family.name(),
        m.seq_len,
        cfg.batch,
    )?;
    let mut session =
        Session::new(&rt, cfg.family, Rc::new(store), batch, m.seq_len)?;
    log_info!(
        "engine up: family={} batch={} (requested {}) seq_len={}",
        cfg.family.name(),
        batch,
        cfg.batch,
        m.seq_len
    );

    let mut waiting: VecDeque<Pending> = VecDeque::new();
    let mut running: Vec<Option<Running>> = (0..batch).map(|_| None).collect();
    let mut metrics = Metrics::default();
    let mut shutdown = false;

    loop {
        // 1) ingest control messages (block only when fully idle)
        let idle = waiting.is_empty() && running.iter().all(Option::is_none);
        if idle && !shutdown {
            match rx.recv() {
                Ok(msg) => {
                    if handle_msg(msg, &mut waiting, &mut metrics, &mut shutdown)
                    {
                        continue;
                    }
                }
                Err(_) => break, // all senders dropped
            }
        }
        while let Ok(msg) = rx.try_recv() {
            handle_msg(msg, &mut waiting, &mut metrics, &mut shutdown);
        }
        if shutdown && waiting.is_empty() && running.iter().all(Option::is_none)
        {
            break;
        }

        // 2) admit waiting requests into free slots (continuous batching);
        //    preflight-resolvable requests never reach the queue (see
        //    handle_msg), so everything here needs a device slot
        for slot in 0..batch {
            if running[slot].is_none() {
                if let Some(p) = waiting.pop_front() {
                    let mut policy = p.req.policy.clone();
                    policy.reset();
                    session.reset_slot(
                        slot,
                        p.req.seed,
                        p.req.n_steps,
                        p.req.noise_scale,
                        cfg.t_max,
                        cfg.t_min,
                        &p.req.prefix,
                    );
                    running[slot] = Some(Running {
                        policy,
                        started: Instant::now(),
                        submitted: p.submitted,
                        req: p.req,
                        reply: p.reply,
                    });
                }
            }
        }

        // 3) one batched device step
        if running.iter().any(Option::is_some) {
            let stats = session.step()?;
            metrics.device_calls += 1;
            for slot in 0..batch {
                let Some(st) = stats[slot] else { continue };
                let Some(r) = running[slot].as_mut() else { continue };
                metrics.steps_executed += 1;
                let executed = session.slots[slot].step;
                let decision = r.policy.observe(executed - 1, &st);
                let exhausted = session.slot_exhausted(slot);
                if decision.halted() || exhausted {
                    let r = running[slot].take().unwrap();
                    let budget = r.req.n_steps;
                    let halted_early = decision.halted() && !exhausted;
                    let resp = GenResponse {
                        id: r.req.id,
                        tokens: session.slot_output(slot),
                        steps_executed: executed,
                        steps_budget: budget,
                        halted_early,
                        halt_reason: if halted_early {
                            decision.reason().map(str::to_string)
                        } else {
                            None
                        },
                        latency_ms: r.started.elapsed().as_secs_f64() * 1e3,
                        queue_ms: (r.started - r.submitted).as_secs_f64()
                            * 1e3,
                        final_stats: st,
                    };
                    metrics.requests_completed += 1;
                    metrics.steps_saved +=
                        (budget.saturating_sub(executed)) as u64;
                    if halted_early {
                        if let Some(reason) = decision.reason() {
                            metrics.record_halt(reason);
                        }
                    }
                    metrics.latency_ms.observe(resp.latency_ms);
                    let _ = r.reply.send(resp);
                    session.release_slot(slot);
                }
            }
        }
    }
    log_info!(
        "engine down: {} completed, saving ratio {:.3}",
        metrics.requests_completed,
        metrics.step_saving_ratio()
    );
    Ok(())
}

fn handle_msg(
    msg: EngineMsg,
    waiting: &mut VecDeque<Pending>,
    metrics: &mut Metrics,
    shutdown: &mut bool,
) -> bool {
    match msg {
        EngineMsg::Submit(req, reply) => {
            metrics.requests_submitted += 1;
            // a policy that resolves before any step (e.g. fixed:0) is
            // answered at ingest — it must not wait for a batch slot
            if let Decision::Halt { reason } = req.policy.preflight() {
                let resp = GenResponse {
                    id: req.id,
                    tokens: Vec::new(),
                    steps_executed: 0,
                    steps_budget: req.n_steps,
                    halted_early: true,
                    halt_reason: Some(reason.to_string()),
                    latency_ms: 0.0,
                    queue_ms: 0.0,
                    final_stats: StepStats::default(),
                };
                metrics.requests_completed += 1;
                metrics.steps_saved += req.n_steps as u64;
                metrics.record_halt(reason);
                metrics.latency_ms.observe(0.0);
                let _ = reply.send(resp);
                return false;
            }
            waiting.push_back(Pending {
                req,
                reply,
                submitted: Instant::now(),
            });
            false
        }
        EngineMsg::Metrics(reply) => {
            let _ = reply.send(metrics.to_json());
            true
        }
        EngineMsg::Shutdown => {
            *shutdown = true;
            false
        }
    }
}
