//! The serving engine: a thin composition of the admission
//! [`Scheduler`] and N worker shards ([`super::worker`]).
//!
//! `start()` builds one shared scheduler (bounded priority queue,
//! deadlines, cancellation, backpressure) and spawns one worker thread
//! per `EngineConfig::worker_batches` entry; each worker owns its own
//! PJRT runtime and a batched `Session` bound to that batch size's
//! compiled artifact.  This is the serving-side payoff of the paper:
//! halting doesn't just cut one request's latency, it raises fleet
//! throughput because every freed batch slot starts the next request
//! `saved_steps` earlier — and with multiple shards, a small-batch
//! worker can soak latency-sensitive traffic while large-batch workers
//! soak throughput traffic.
//!
//! [`EngineHandle`] is the cheap, cloneable front-end: blocking
//! `submit`/`generate`, non-blocking `try_submit` (typed `overloaded`
//! rejection), `cancel(id)`, a merged fleet `metrics()` snapshot, and
//! `shutdown()` (drain then exit).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse};
use super::scheduler::{CancelOutcome, GenOutcome, Scheduler, ServeError};
use super::worker::{self, WorkerConfig};
use crate::sampler::Family;
use crate::util::json::Json;

pub struct EngineConfig {
    pub artifact_dir: String,
    pub family: Family,
    /// one worker thread per entry: the batch size that worker requests
    /// (resolved to the nearest compiled artifact).  Mixing sizes shards
    /// traffic — e.g. `vec![1, 8]` runs a latency shard next to a
    /// throughput shard of the same model family.
    pub worker_batches: Vec<usize>,
    /// trained checkpoint (PBIN); falls back to init params when None
    pub checkpoint: Option<String>,
    pub t_max: f32,
    pub t_min: f32,
    /// admission-queue bound (all priority classes combined); submits
    /// beyond it are rejected with a typed `overloaded` error
    pub queue_depth: usize,
}

impl EngineConfig {
    pub fn new(artifact_dir: &str, family: Family) -> EngineConfig {
        EngineConfig {
            artifact_dir: artifact_dir.to_string(),
            family,
            worker_batches: vec![8],
            checkpoint: None,
            t_max: 10.0,
            t_min: 0.05,
            queue_depth: 256,
        }
    }
}

/// Cloneable front-end to the scheduler + worker fleet.
#[derive(Clone)]
pub struct EngineHandle {
    sched: Arc<Scheduler>,
    worker_metrics: Vec<Arc<Mutex<Metrics>>>,
}

impl EngineHandle {
    /// Submit a request; returns the receiver for its outcome.  Failures
    /// (overload, cancellation, deadline expiry) arrive through the
    /// channel as `Err(ServeError)`.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenOutcome> {
        let (tx, rx) = mpsc::channel();
        if let Err(e) = self.sched.submit(req, tx.clone()) {
            let _ = tx.send(Err(e));
        }
        rx
    }

    /// Non-blocking admission: a full queue returns `Err(Overloaded)`
    /// synchronously instead of through the channel.
    pub fn try_submit(
        &self,
        req: GenRequest,
    ) -> Result<mpsc::Receiver<GenOutcome>, ServeError> {
        let (tx, rx) = mpsc::channel();
        self.sched.submit(req, tx)?;
        Ok(rx)
    }

    /// Convenience: submit and wait (serve errors become `anyhow` ones).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        Ok(self.submit(req).recv()??)
    }

    /// Cancel a queued or running request by id.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        self.sched.cancel(id)
    }

    /// Merged fleet snapshot: the scheduler's admission metrics folded
    /// with every worker's, plus queue-depth / slot-occupancy gauges and
    /// a per-worker breakdown under `"workers"`.
    pub fn metrics(&self) -> Result<Json> {
        let mut merged = self.sched.metrics.lock().unwrap().clone();
        let mut per_worker = Vec::new();
        for (i, wm) in self.worker_metrics.iter().enumerate() {
            let w = wm.lock().unwrap().clone();
            per_worker.push(Json::obj(vec![
                ("worker", Json::num(i as f64)),
                ("slots_total", Json::num(w.slots_total as f64)),
                ("slots_busy", Json::num(w.slots_busy as f64)),
                (
                    "requests_completed",
                    Json::num(w.requests_completed as f64),
                ),
                ("steps_executed", Json::num(w.steps_executed as f64)),
                ("device_calls", Json::num(w.device_calls as f64)),
            ]));
            merged.merge(&w);
        }
        let Json::Obj(mut m) = merged.to_json() else { unreachable!() };
        m.insert(
            "queue_depth".to_string(),
            Json::num(self.sched.queue_depth() as f64),
        );
        m.insert(
            "running_requests".to_string(),
            Json::num(self.sched.running_count() as f64),
        );
        m.insert("workers".to_string(), Json::Arr(per_worker));
        Ok(Json::Obj(m))
    }

    /// Stop admitting new work; workers drain the queue and exit.
    pub fn shutdown(&self) {
        self.sched.shutdown();
    }
}

/// Join handle over the worker fleet; `join()` surfaces the first worker
/// error (mirroring the old single-thread engine contract).
pub struct EngineJoin {
    handles: Vec<JoinHandle<Result<()>>>,
}

impl EngineJoin {
    pub fn join(self) -> std::thread::Result<Result<()>> {
        // join EVERY worker before propagating anything: bailing on the
        // first panic would detach the surviving workers mid-drain and
        // swallow their errors
        let mut first_panic = None;
        let mut first_err = Ok(());
        for h in self.handles {
            match h.join() {
                Ok(r) => {
                    if first_err.is_ok() && r.is_err() {
                        first_err = r;
                    }
                }
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        match first_panic {
            Some(p) => Err(p),
            None => Ok(first_err),
        }
    }
}

/// Spawn the scheduler + worker fleet; returns a cloneable handle plus
/// the fleet join handle (joining after `shutdown()` surfaces worker
/// errors).
pub fn start(cfg: EngineConfig) -> (EngineHandle, EngineJoin) {
    let mut sched =
        Scheduler::new(cfg.queue_depth, cfg.worker_batches.len());
    // admission-side validation needs the compiled seq_len (a longer
    // prefix must reject with `invalid_request` at the boundary, not
    // panic a worker).  The manifest read is cheap; if it fails the
    // workers will surface the real error and enforce the bound
    // themselves.
    if let Ok(man) = crate::runtime::Manifest::load(&cfg.artifact_dir) {
        sched = sched.with_max_prefix(man.model.seq_len);
    }
    let sched = Arc::new(sched);
    let mut handles = Vec::new();
    let mut worker_metrics = Vec::new();
    for (id, &batch) in cfg.worker_batches.iter().enumerate() {
        let m = Arc::new(Mutex::new(Metrics::default()));
        worker_metrics.push(m.clone());
        handles.push(worker::spawn(
            WorkerConfig {
                id,
                artifact_dir: cfg.artifact_dir.clone(),
                family: cfg.family,
                batch,
                checkpoint: cfg.checkpoint.clone(),
                t_max: cfg.t_max,
                t_min: cfg.t_min,
            },
            sched.clone(),
            m,
        ));
    }
    (
        EngineHandle {
            sched,
            worker_metrics,
        },
        EngineJoin { handles },
    )
}
