//! The serving engine: a thin composition of the admission
//! [`Scheduler`] and N worker shards ([`super::worker`]).
//!
//! `start()` builds one shared scheduler (bounded priority queue,
//! deadlines, cancellation, backpressure, per-family routing) and spawns
//! one worker thread per `EngineConfig::worker_specs` entry; each worker
//! owns its own PJRT runtime and a batched `Session` bound to that
//! entry's `(family, batch)` compiled artifact.  This is the serving-side
//! payoff of the paper: halting doesn't just cut one request's latency,
//! it raises fleet throughput because every freed batch slot starts the
//! next request `saved_steps` earlier — and with heterogeneous shards,
//! one fleet serves every model family at once (a small-batch ddlm
//! worker next to a large-batch ssd worker, say), with requests routed
//! by their `family` wire field.
//!
//! [`EngineHandle`] is the cheap, cloneable front-end: blocking
//! `submit`/`generate`, non-blocking `try_submit` (typed `overloaded`
//! rejection), `cancel(id)`, a merged fleet `metrics()` snapshot (with
//! per-family counters), and `shutdown()` (drain then exit).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::journal::Journal;
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse, Priority};
use super::scheduler::{
    CancelOutcome, GenOutcome, ProgressTx, RebindOrder, RebindReport,
    Scheduler, ServeError,
};
use super::worker::{self, WorkerConfig};
use crate::predictor::{Estimator, PredictorConfig};
use crate::sampler::FamilyId;
use crate::util::sync::lock_or_recover;
use crate::util::json::Json;

/// `--fleet auto` supervisor cadence.
const SUPERVISOR_TICK_MS: u64 = 100;

/// Queued requests a family must accumulate before the supervisor
/// pulls an idle worker over from a quiet family.
const SUPERVISOR_STARVED_QUEUE: usize = 2;

pub struct EngineConfig {
    pub artifact_dir: String,
    /// family assumed for requests that don't carry a `family` field —
    /// every pre-multi-family client keeps working unchanged
    pub default_family: FamilyId,
    /// one worker thread per entry: `(family, batch)` — the model
    /// family that worker serves and the batch size it requests
    /// (resolved to the nearest compiled artifact).  Mixing entries
    /// shards traffic by latency class *and* family — e.g.
    /// `vec![(Ddlm.into(), 1), (Ddlm.into(), 8), (Ssd.into(), 8)]`
    /// runs a ddlm latency shard, a ddlm throughput shard, and an ssd
    /// shard behind one scheduler.  Families are registry ids, so a
    /// kernel registered at runtime is a valid shard spec.
    pub worker_specs: Vec<(FamilyId, usize)>,
    /// trained checkpoints (PBIN) per family; workers of a family
    /// without an entry fall back to init params
    pub checkpoints: Vec<(FamilyId, String)>,
    /// fleet-wide schedule envelope, used by every family without an
    /// override below
    pub t_max: f32,
    pub t_min: f32,
    /// per-family `(family, t_max, t_min)` overrides (ROADMAP open
    /// item): a family's workers build their schedules inside this
    /// envelope instead of the fleet default.  Surfaced to clients in
    /// the metrics snapshot under `"families"`.
    pub schedule_overrides: Vec<(FamilyId, f32, f32)>,
    /// admission-queue bound (all priority classes combined); submits
    /// beyond it are rejected with a typed `overloaded` error
    pub queue_depth: usize,
    /// optional per-priority-class queue bounds (high/normal/low in
    /// `Priority::index()` order); a full class rejects with typed
    /// `overloaded` without starving the other classes
    pub class_queue_bounds: Option<[usize; Priority::COUNT]>,
    /// optional per-family queue bounds: a family whose queued count
    /// reaches its cap rejects new submits with typed `overloaded`
    /// without blocking the other families' admission
    pub family_queue_bounds: Vec<(FamilyId, usize)>,
    /// completeness-predictor wiring (wire fields, admission gate,
    /// SRPT packing); the default leaves every gate off and behavior
    /// bit-identical to a predictor-less build
    pub predictor: PredictorConfig,
    /// frozen-aware live slot migration: workers hand mostly-frozen
    /// long-tail slots to a smaller live shard of the same family
    pub migrate: bool,
    /// `--fleet auto`: a supervisor thread watches queue depth per
    /// family and rebinds idle workers toward backlogged families
    /// (implies `migrate`)
    pub fleet_auto: bool,
    /// write-ahead admission journal path (`--journal`): every queued
    /// admission and terminal resolution is logged, and `start()`
    /// replays any incomplete set left by a previous process before
    /// taking new traffic.  `None` (the default) journals nothing.
    pub journal_path: Option<String>,
    /// worker-death retry budget (`--retry-budget`): in-flight
    /// requests on a dead worker are re-queued up to this many times
    /// (exponential backoff) before failing with `unavailable`.  The
    /// default `0` keeps the pre-journal fail-fast semantics.
    pub retry_budget: u32,
    /// brownout hysteresis window (`--brownout`): arms the fleet-health
    /// machine — under queue pressure or worker loss the engine
    /// degrades (progress fan-out and predictor grading suspended,
    /// low-priority queue shed) and recovers only after the pressure
    /// has stayed clear this many milliseconds.  `None` (the default)
    /// leaves the machine off.
    pub brownout_recover_ms: Option<u64>,
}

impl EngineConfig {
    pub fn new(
        artifact_dir: &str,
        family: impl Into<FamilyId>,
    ) -> EngineConfig {
        let family = family.into();
        EngineConfig {
            artifact_dir: artifact_dir.to_string(),
            default_family: family,
            worker_specs: vec![(family, 8)],
            checkpoints: Vec::new(),
            t_max: 10.0,
            t_min: 0.05,
            schedule_overrides: Vec::new(),
            queue_depth: 256,
            class_queue_bounds: None,
            family_queue_bounds: Vec::new(),
            predictor: PredictorConfig::default(),
            migrate: false,
            fleet_auto: false,
            journal_path: None,
            retry_budget: 0,
            brownout_recover_ms: None,
        }
    }

    /// Probe `runs_dir` for per-family trained checkpoints
    /// (`<runs_dir>/<artifact_prefix>.pbin`) for every family in
    /// `worker_specs` and register each one found (families with an
    /// explicit entry keep it) — the one checkpoint-discovery path
    /// shared by the CLI, examples and benches.  Registered wrapper
    /// kernels discover the checkpoint of the family whose artifacts
    /// they reuse.
    pub fn discover_checkpoints(&mut self, runs_dir: &str) {
        let fams: Vec<FamilyId> =
            self.worker_specs.iter().map(|&(f, _)| f).collect();
        for f in fams {
            let path = format!(
                "{runs_dir}/{}.pbin",
                f.kernel().artifact_prefix()
            );
            if std::path::Path::new(&path).exists()
                && !self.checkpoints.iter().any(|(cf, _)| *cf == f)
            {
                self.checkpoints.push((f, path));
            }
        }
    }

    /// Resolved `(t_max, t_min)` for one family: its override, else
    /// the fleet default.
    fn schedule_for(&self, family: FamilyId) -> (f32, f32) {
        self.schedule_overrides
            .iter()
            .find(|&&(f, ..)| f == family)
            .map(|&(_, t_max, t_min)| (t_max, t_min))
            .unwrap_or((self.t_max, self.t_min))
    }
}

/// Cloneable front-end to the scheduler + worker fleet.
#[derive(Clone)]
pub struct EngineHandle {
    sched: Arc<Scheduler>,
    /// (family, metrics) per worker, in spawn order
    worker_metrics: Vec<(FamilyId, Arc<Mutex<Metrics>>)>,
    /// resolved `(family, t_max, t_min)` per served family — the
    /// schedule envelope clients see in the metrics snapshot
    schedule_envelope: Vec<(FamilyId, f32, f32)>,
    /// shared steps-to-halt estimator, present when any predictor
    /// feature is active; its per-family state appears in the metrics
    /// snapshot under `"predictor"`
    predictor: Option<Arc<Estimator>>,
    /// write-ahead admission journal, when configured; its counters
    /// appear in the metrics snapshot under `journal_*`
    journal: Option<Arc<Journal>>,
}

impl EngineHandle {
    /// Submit a request; returns the receiver for its outcome.  Failures
    /// (overload, cancellation, deadline expiry) arrive through the
    /// channel as `Err(ServeError)`.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenOutcome> {
        self.submit_with_progress(req, None)
    }

    /// [`Self::submit`] with an optional progress subscriber: the
    /// owning worker streams a `ProgressEvent` every
    /// `req.progress_every` executed steps until the request finishes
    /// (sender dropped = end of stream).  Admission failures still
    /// arrive through the returned outcome channel.
    pub fn submit_with_progress(
        &self,
        req: GenRequest,
        progress: Option<ProgressTx>,
    ) -> mpsc::Receiver<GenOutcome> {
        let (tx, rx) = mpsc::channel();
        if let Err(e) =
            self.sched.submit_with_progress(req, tx.clone(), progress)
        {
            let _ = tx.send(Err(e));
        }
        rx
    }

    /// Non-blocking admission: a full queue returns `Err(Overloaded)`
    /// synchronously instead of through the channel.
    pub fn try_submit(
        &self,
        req: GenRequest,
    ) -> Result<mpsc::Receiver<GenOutcome>, ServeError> {
        let (tx, rx) = mpsc::channel();
        self.sched.submit(req, tx)?;
        Ok(rx)
    }

    /// Convenience: submit and wait (serve errors become `anyhow` ones).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        Ok(self.submit(req).recv()??)
    }

    /// Cancel a queued or running request by id.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        self.sched.cancel(id)
    }

    /// Gracefully halt a queued or running request by id: the
    /// submitter receives a *normal* completion with the current x0
    /// decode and `halt_reason:"client"` — the client-visible form of
    /// the paper's early exit, distinct from [`Self::cancel`].
    pub fn halt(&self, id: u64) -> CancelOutcome {
        self.sched.halt(id)
    }

    /// Live-rebind one worker shard: drain its in-flight slots back to
    /// the queue as resumable state, rebuild its session under the new
    /// `(family, batch, checkpoint)` and rejoin — zero requests
    /// dropped.  `None` keeps the worker's current value; an empty
    /// checkpoint string drops back to init params.  Blocks until the
    /// worker reports (or typed refusal / failure-and-revert).
    pub fn rebind(
        &self,
        worker: usize,
        family: Option<FamilyId>,
        batch: Option<usize>,
        checkpoint: Option<String>,
    ) -> Result<RebindReport, String> {
        let (tx, rx) = mpsc::channel();
        self.sched
            .request_rebind(
                worker,
                RebindOrder {
                    family,
                    batch,
                    checkpoint,
                    reply: Some(tx),
                },
            )
            .map_err(str::to_string)?;
        rx.recv()
            .map_err(|_| "worker exited during rebind".to_string())?
    }

    /// Merged fleet snapshot: the scheduler's admission metrics folded
    /// with every worker's — including the per-family completion/latency
    /// counters — plus queue-depth / slot-occupancy gauges and a
    /// per-worker breakdown (with each worker's family) under
    /// `"workers"`.
    pub fn metrics(&self) -> Result<Json> {
        let mut merged = lock_or_recover(&self.sched.metrics).clone();
        let mut per_worker = Vec::new();
        for (i, (family, wm)) in self.worker_metrics.iter().enumerate() {
            let w = lock_or_recover(&wm).clone();
            per_worker.push(Json::obj(vec![
                ("worker", Json::num(i as f64)),
                ("family", Json::str(family.name())),
                ("slots_total", Json::num(w.slots_total as f64)),
                ("slots_busy", Json::num(w.slots_busy as f64)),
                (
                    "requests_completed",
                    Json::num(w.requests_completed as f64),
                ),
                ("steps_executed", Json::num(w.steps_executed as f64)),
                ("device_calls", Json::num(w.device_calls as f64)),
            ]));
            merged.merge(&w);
        }
        let mut m = merged.to_json().into_obj();
        m.insert(
            "queue_depth".to_string(),
            Json::num(self.sched.queue_depth() as f64),
        );
        m.insert(
            "running_requests".to_string(),
            Json::num(self.sched.running_count() as f64),
        );
        m.insert("workers".to_string(), Json::Arr(per_worker));
        // lock-poison recoveries survive as a conditional key, like the
        // other feature-fired counters: absent until the first recovery
        let poisoned = crate::util::sync::poisoned_count();
        if poisoned > 0 {
            m.insert(
                "lock_poisoned".to_string(),
                Json::num(poisoned as f64),
            );
        }
        // fleet-health verdict: present only when the brownout machine
        // is armed, so unarmed snapshots keep their exact key set
        if self.sched.brownout_enabled() {
            m.insert(
                "fleet_health".to_string(),
                Json::str(self.sched.health().as_str()),
            );
        }
        // write-ahead journal counters: present only when a journal is
        // configured
        if let Some(j) = &self.journal {
            m.insert(
                "journal_records".to_string(),
                Json::num(j.records() as f64),
            );
            m.insert(
                "journal_replayed".to_string(),
                Json::num(j.replayed() as f64),
            );
            m.insert(
                "journal_truncated_records".to_string(),
                Json::num(j.truncated_records() as f64),
            );
            m.insert(
                "journal_bytes".to_string(),
                Json::num(j.bytes() as f64),
            );
            m.insert(
                "journal_write_failures".to_string(),
                Json::num(j.write_failures() as f64),
            );
        }
        // deterministic fault injection: one `faults_injected_<point>`
        // lane per fault point that has actually fired
        for (point, n) in crate::util::fault::fired_counts() {
            m.insert(
                format!("faults_injected_{point}"),
                Json::num(n as f64),
            );
        }
        // process-wide artifact cache: mmap'd checkpoint/manifest bytes
        // shared across workers and rebinds.  Always present (even all
        // zero) so operators can watch hit rate and resident bytes.
        let cs = crate::runtime::artifact_cache::global().stats();
        m.insert(
            "artifact_cache_hits".to_string(),
            Json::num(cs.hits as f64),
        );
        m.insert(
            "artifact_cache_misses".to_string(),
            Json::num(cs.misses as f64),
        );
        m.insert(
            "artifact_cache_evictions".to_string(),
            Json::num(cs.evictions as f64),
        );
        m.insert(
            "artifact_cache_bytes".to_string(),
            Json::num(cs.bytes as f64),
        );
        // per-family schedule envelope (t_max/t_min, including any
        // per-family overrides) so remote clients can see the schedule
        // each family's workers generate under
        let families: Vec<(&str, Json)> = self
            .schedule_envelope
            .iter()
            .map(|&(f, t_max, t_min)| {
                (
                    f.name(),
                    Json::obj(vec![
                        ("t_max", Json::num(t_max as f64)),
                        ("t_min", Json::num(t_min as f64)),
                    ]),
                )
            })
            .collect();
        m.insert("families".to_string(), Json::obj(families));
        if let Some(est) = &self.predictor {
            m.insert("predictor".to_string(), est.snapshot_json());
        }
        Ok(Json::Obj(m))
    }

    /// Suggested client backoff for overload/unavailable answers —
    /// `None` while the fleet is healthy, a hint in milliseconds while
    /// degraded or browned out.  The server attaches it to error
    /// frames as `retry_after_ms`.
    pub fn retry_after_ms(&self) -> Option<u64> {
        self.sched.health().retry_after_ms()
    }

    /// The write-ahead admission journal, when one is configured —
    /// benches and chaos tests use it to simulate a crash (`seal()`)
    /// and inspect replay counters.
    pub fn journal(&self) -> Option<&Arc<Journal>> {
        self.journal.as_ref()
    }

    /// Stop admitting new work; workers drain the queue and exit.
    pub fn shutdown(&self) {
        self.sched.shutdown();
    }
}

/// Join handle over the worker fleet; `join()` surfaces the first worker
/// error (mirroring the old single-thread engine contract).
pub struct EngineJoin {
    handles: Vec<JoinHandle<Result<()>>>,
}

impl EngineJoin {
    pub fn join(self) -> std::thread::Result<Result<()>> {
        // join EVERY worker before propagating anything: bailing on the
        // first panic would detach the surviving workers mid-drain and
        // swallow their errors
        let mut first_panic = None;
        let mut first_err = Ok(());
        for h in self.handles {
            match h.join() {
                Ok(r) => {
                    if first_err.is_ok() && r.is_err() {
                        first_err = r;
                    }
                }
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        match first_panic {
            Some(p) => Err(p),
            None => Ok(first_err),
        }
    }
}

/// Spawn the scheduler + worker fleet; returns a cloneable handle plus
/// the fleet join handle (joining after `shutdown()` surfaces worker
/// errors).
pub fn start(cfg: EngineConfig) -> (EngineHandle, EngineJoin) {
    let families: Vec<FamilyId> =
        cfg.worker_specs.iter().map(|&(f, _)| f).collect();
    // a default family nobody serves would reject every family-less
    // (pre-multi-family) request with invalid_request forever — fall
    // back loudly to the first worker's family instead of building a
    // silently-broken fleet (the CLI additionally refuses the
    // misconfiguration up front)
    let default_family = if families.contains(&cfg.default_family) {
        cfg.default_family
    } else if let Some(&first) = families.first() {
        crate::log_warn!(
            "engine: default family {} has no worker — falling back to {}",
            cfg.default_family.name(),
            first.name()
        );
        first
    } else {
        cfg.default_family
    };
    let mut sched = Scheduler::new(cfg.queue_depth, families)
        .with_default_family(default_family);
    if let Some(caps) = cfg.class_queue_bounds {
        sched = sched.with_class_caps(caps);
    }
    if !cfg.family_queue_bounds.is_empty() {
        sched = sched.with_family_caps(cfg.family_queue_bounds.clone());
    }
    // one estimator shared by the scheduler (admission + packing) and
    // every worker (observation + wire predictions); absent entirely
    // when no predictor feature is on, so the default config cannot
    // perturb scheduling or the wire
    let estimator = cfg
        .predictor
        .active()
        .then(|| Arc::new(Estimator::new()));
    if let Some(est) = &estimator {
        sched = sched.with_predictor(
            est.clone(),
            cfg.predictor.admission,
            cfg.predictor.packing,
        );
    }
    // admission-side validation needs the compiled seq_len (a longer
    // prefix must reject with `invalid_request` at the boundary, not
    // panic a worker).  The manifest read is cheap; if it fails the
    // workers will surface the real error and enforce the bound
    // themselves.
    if let Ok(man) = crate::runtime::Manifest::load(&cfg.artifact_dir) {
        sched = sched.with_max_prefix(man.model.seq_len);
    }
    // write-ahead admission journal: open (self-healing any torn tail
    // left by a crash) and keep the incomplete set to re-admit once
    // the workers are up.  An unusable journal path degrades loudly to
    // journal-less serving rather than refusing to serve at all.
    let mut replay_incomplete: Vec<GenRequest> = Vec::new();
    let journal = match &cfg.journal_path {
        Some(path) => match Journal::open(path) {
            Ok((j, replay)) => {
                replay_incomplete = replay.incomplete;
                Some(Arc::new(j))
            }
            Err(e) => {
                crate::log_warn!(
                    "engine: journal {path} unavailable ({e}); \
                     serving without crash recovery"
                );
                None
            }
        },
        None => None,
    };
    if let Some(j) = &journal {
        sched = sched.with_journal(j.clone());
    }
    if cfg.retry_budget > 0 {
        sched = sched.with_retry_budget(cfg.retry_budget);
    }
    if let Some(ms) = cfg.brownout_recover_ms {
        sched = sched.with_brownout(ms);
    }
    let sched = Arc::new(sched);
    let mut handles = Vec::new();
    let mut worker_metrics = Vec::new();
    let mut schedule_envelope: Vec<(FamilyId, f32, f32)> = Vec::new();
    for (id, &(family, batch)) in cfg.worker_specs.iter().enumerate() {
        let m = Arc::new(Mutex::new(Metrics::default()));
        worker_metrics.push((family, m.clone()));
        let checkpoint = cfg
            .checkpoints
            .iter()
            .find(|(f, _)| *f == family)
            .map(|(_, p)| p.clone());
        // per-family t_max/t_min override, else the fleet default
        let (t_max, t_min) = cfg.schedule_for(family);
        if !schedule_envelope.iter().any(|&(f, ..)| f == family) {
            schedule_envelope.push((family, t_max, t_min));
        }
        handles.push(worker::spawn(
            WorkerConfig {
                id,
                artifact_dir: cfg.artifact_dir.clone(),
                family,
                batch,
                checkpoint,
                checkpoints: cfg.checkpoints.clone(),
                t_max,
                t_min,
                predictor: estimator.clone(),
                predict_wire: cfg.predictor.enabled,
                migrate: cfg.migrate || cfg.fleet_auto,
            },
            sched.clone(),
            m,
        ));
    }
    if cfg.fleet_auto {
        let s = sched.clone();
        handles.push(std::thread::spawn(move || {
            fleet_supervisor(&s);
            Ok(())
        }));
    }
    // crash recovery: re-admit the incomplete set the journal replay
    // surfaced.  The submitters died with the previous process, so the
    // outcome receivers are dropped immediately — the work still runs
    // to completion and `Reply` journals every resolution before
    // forwarding, so a second restart replays only what this one
    // leaves unfinished.
    if !replay_incomplete.is_empty() {
        let n = replay_incomplete.len() as u64;
        crate::log_info!(
            "engine: replaying {n} incomplete request(s) from the \
             admission journal"
        );
        for req in replay_incomplete {
            let id = req.id;
            let (tx, _rx) = mpsc::channel();
            if let Err(e) = sched.submit(req, tx) {
                // rejected at re-admission (say, a shrunken queue):
                // resolve the journal record so it cannot resurrect on
                // every subsequent restart
                crate::log_warn!(
                    "engine: replayed request {id} rejected: {}",
                    e.as_str()
                );
                if let Some(j) = &journal {
                    j.resolve(id, e.as_str());
                }
            }
        }
        if let Some(j) = &journal {
            j.note_replayed(n);
        }
    }
    (
        EngineHandle {
            sched,
            worker_metrics,
            schedule_envelope,
            predictor: estimator,
            journal,
        },
        EngineJoin { handles },
    )
}

/// The `--fleet auto` supervisor: each tick, find the family with the
/// deepest backlog and — if a quiet family has an idle worker to
/// spare — rebind that worker toward the backlog.  One rebind per
/// tick, never while another is settling, and never the last live
/// worker of a family (that would strand its queued work).  Exits when
/// the scheduler shuts down.
fn fleet_supervisor(sched: &Scheduler) {
    loop {
        if sched.is_shutdown() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(
            SUPERVISOR_TICK_MS,
        ));
        let snap = sched.fleet_snapshot();
        // let an in-flight rebind settle before judging the new shape
        if snap.workers.iter().any(|w| w.rebind_pending) {
            continue;
        }
        // deepest backlog first
        let mut starved: Vec<(usize, usize)> = snap
            .queued_by_family
            .iter()
            .enumerate()
            .filter(|&(_, &q)| q >= SUPERVISOR_STARVED_QUEUE)
            .map(|(f, &q)| (f, q))
            .collect();
        starved.sort_by(|a, b| b.1.cmp(&a.1));
        'tick: for (fi, backlog) in starved {
            // queued work implies a live worker of that family exists —
            // recover its FamilyId from the fleet
            let Some(fam) = snap
                .workers
                .iter()
                .find(|w| w.alive && w.family.index() == fi)
                .map(|w| w.family)
            else {
                continue;
            };
            for w in &snap.workers {
                if !w.alive || w.running > 0 || w.family == fam {
                    continue;
                }
                // the donor family must be quiet and keep at least one
                // other live worker
                let donor_queue = snap
                    .queued_by_family
                    .get(w.family.index())
                    .copied()
                    .unwrap_or(0);
                if donor_queue > 0 {
                    continue;
                }
                let peers = snap
                    .workers
                    .iter()
                    .filter(|o| o.alive && o.family == w.family)
                    .count();
                if peers < 2 {
                    continue;
                }
                crate::log_info!(
                    "fleet auto: rebinding idle worker {} ({} -> {}, \
                     backlog {})",
                    w.worker,
                    w.family.name(),
                    fam.name(),
                    backlog
                );
                // fire-and-forget: the worker reports through metrics
                let _ = sched.request_rebind(
                    w.worker,
                    RebindOrder {
                        family: Some(fam),
                        batch: None,
                        checkpoint: None,
                        reply: None,
                    },
                );
                break 'tick;
            }
        }
    }
}
