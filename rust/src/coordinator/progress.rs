//! Bounded per-subscriber progress fan-out.
//!
//! The progress stream used to ride a plain `mpsc::channel()`: an
//! unbounded buffer per subscriber, so one slow (or stalled) progress
//! reader made the fleet accumulate frames without limit while its
//! request kept stepping.  This channel bounds each subscriber to a
//! fixed window of the *most recent* frames: a send over capacity
//! evicts the oldest buffered frame (drop-oldest) rather than blocking
//! the worker's hot loop or growing without bound.  Progress frames
//! are periodic snapshots — the newest one supersedes the ones before
//! it — so drop-oldest loses only stale intermediate state, never the
//! freshest view.
//!
//! [`Sender::send`] reports how many frames it evicted so the worker
//! can account them (`progress_dropped` in the metrics snapshot), and
//! fails typed once the receiver is gone so dead subscribers are
//! dropped on the first failed send exactly like the old channel.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::util::sync::{lock_or_recover, wait_or_recover};

/// Default per-subscriber buffer, in frames.  Progress cadence is
/// client-chosen (`progress_every`), so the window is sized in frames
/// rather than bytes: 64 frames of headroom absorbs a reader stalled
/// for a full schedule at the default cadence without letting one
/// subscriber hold more than a screenful of stale snapshots.
pub const DEFAULT_PROGRESS_BUFFER: usize = 64;

/// The receiver is gone; the subscription is over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Disconnected;

impl std::fmt::Display for Disconnected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("progress receiver disconnected")
    }
}

impl std::error::Error for Disconnected {}

struct Inner<T> {
    buf: VecDeque<T>,
    cap: usize,
    /// total frames evicted by drop-oldest over this channel's lifetime
    dropped: u64,
    tx_count: usize,
    rx_alive: bool,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    avail: Condvar,
}

/// Bounded drop-oldest sender; clones share the one buffer.
pub struct Sender<T>(Arc<Shared<T>>);

/// Receiving half; dropping it fails every later send typed.
pub struct Receiver<T>(Arc<Shared<T>>);

/// A bounded progress channel holding at most `cap` in-flight frames
/// (minimum 1).  Sends beyond capacity evict the oldest frame.
pub fn channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            buf: VecDeque::new(),
            cap: cap.max(1),
            dropped: 0,
            tx_count: 1,
            rx_alive: true,
        }),
        avail: Condvar::new(),
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Sender<T> {
    /// Buffer one frame.  Returns how many older frames this send had
    /// to evict (0 on the uncongested path) or [`Disconnected`] once
    /// the receiver is gone — the caller's cue to drop the subscriber.
    pub fn send(&self, v: T) -> Result<u64, Disconnected> {
        let mut g = lock_or_recover(&self.0.inner);
        if !g.rx_alive {
            return Err(Disconnected);
        }
        let mut evicted = 0u64;
        while g.buf.len() >= g.cap {
            g.buf.pop_front();
            evicted += 1;
        }
        g.buf.push_back(v);
        g.dropped += evicted;
        drop(g);
        self.0.avail.notify_one();
        Ok(evicted)
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        lock_or_recover(&self.0.inner).tx_count += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let senders = {
            let mut g = lock_or_recover(&self.0.inner);
            g.tx_count -= 1;
            g.tx_count
        };
        if senders == 0 {
            // end-of-stream: wake a receiver blocked in recv()
            self.0.avail.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Block for the next frame; `Err(Disconnected)` means every
    /// sender is gone and the buffer is drained (end of stream).
    pub fn recv(&self) -> Result<T, Disconnected> {
        let mut g = lock_or_recover(&self.0.inner);
        loop {
            if let Some(v) = g.buf.pop_front() {
                return Ok(v);
            }
            if g.tx_count == 0 {
                return Err(Disconnected);
            }
            g = wait_or_recover(&self.0.avail, g);
        }
    }

    /// Non-blocking receive: `None` when no frame is buffered (whether
    /// or not senders remain).
    pub fn try_recv(&self) -> Option<T> {
        lock_or_recover(&self.0.inner).buf.pop_front()
    }

    /// Total frames evicted by drop-oldest since the channel opened.
    pub fn dropped(&self) -> u64 {
        lock_or_recover(&self.0.inner).dropped
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut g = lock_or_recover(&self.0.inner);
        g.rx_alive = false;
        // frames nobody will read: surface them in the drop count so
        // accounting stays truthful even for abandoned subscribers
        g.dropped += g.buf.len() as u64;
        g.buf.clear();
    }
}

impl<T> Iterator for Receiver<T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        self.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_flow_in_order_under_capacity() {
        let (tx, rx) = channel(4);
        for i in 0..3 {
            assert_eq!(tx.send(i), Ok(0));
        }
        assert_eq!(rx.try_recv(), Some(0));
        assert_eq!(rx.try_recv(), Some(1));
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.try_recv(), None);
        assert_eq!(rx.dropped(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_reports_the_eviction() {
        let (tx, rx) = channel(2);
        assert_eq!(tx.send(1), Ok(0));
        assert_eq!(tx.send(2), Ok(0));
        // buffer full: the oldest frame (1) is evicted, not the new one
        assert_eq!(tx.send(3), Ok(1));
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.try_recv(), Some(3));
        assert_eq!(rx.dropped(), 1);
    }

    #[test]
    fn send_after_receiver_drop_is_typed() {
        let (tx, rx) = channel(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(Disconnected));
    }

    #[test]
    fn recv_after_last_sender_drop_ends_the_stream() {
        let (tx, rx) = channel(4);
        tx.send(1).unwrap();
        drop(tx);
        // the buffered frame is still delivered, then end-of-stream
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(Disconnected));
    }

    #[test]
    fn iterator_drains_then_ends() {
        let (tx, rx) = channel(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = rx.collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn blocked_recv_wakes_on_send() {
        let (tx, rx) = channel(2);
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(std::time::Duration::from_millis(10));
        tx.send(42).unwrap();
        assert_eq!(h.join().unwrap(), Ok(42));
    }

    #[test]
    fn receiver_drop_counts_abandoned_frames() {
        let (tx, rx) = channel(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(rx);
        // both buffered frames were abandoned unread; the next send
        // fails typed rather than buffering into the void
        assert_eq!(tx.send(3), Err(Disconnected));
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let (tx, rx) = channel(0);
        assert_eq!(tx.send(1), Ok(0));
        assert_eq!(tx.send(2), Ok(1));
        assert_eq!(rx.try_recv(), Some(2));
    }
}
