//! Request/response types + JSON wire codecs for the serving API.

use anyhow::{anyhow, Result};

use crate::halting::{Criterion, StepStats};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    /// conditioning prefix tokens (empty = unconditional)
    pub prefix: Vec<i32>,
    /// maximum diffusion steps (N_max)
    pub n_steps: usize,
    /// early-exit criterion for this request
    pub criterion: Criterion,
    /// initial noise scale (paper Fig 3 / Table 1 knob)
    pub noise_scale: f32,
    pub seed: u64,
}

impl GenRequest {
    pub fn new(id: u64, n_steps: usize) -> GenRequest {
        GenRequest {
            id,
            prefix: Vec::new(),
            n_steps,
            criterion: Criterion::None,
            noise_scale: 1.0,
            seed: id,
        }
    }

    pub fn to_json(&self) -> Json {
        let crit = match self.criterion {
            Criterion::None => "none".to_string(),
            Criterion::Entropy { threshold } => format!("entropy:{threshold}"),
            Criterion::Patience { patience, tolerance } => {
                format!("patience:{patience}:{tolerance}")
            }
            Criterion::Kl { threshold, min_steps } => {
                format!("kl:{threshold}:{min_steps}")
            }
            Criterion::Fixed { step } => format!("fixed:{step}"),
        };
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            (
                "prefix",
                Json::Arr(
                    self.prefix.iter().map(|&t| Json::num(t as f64)).collect(),
                ),
            ),
            ("steps", Json::num(self.n_steps as f64)),
            ("criterion", Json::str(crit)),
            ("noise_scale", Json::num(self.noise_scale as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing id"))? as u64;
        let n_steps = j
            .get("steps")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("missing steps"))?;
        let prefix = j
            .get("prefix")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_f64().map(|v| v as i32))
                    .collect()
            })
            .unwrap_or_default();
        let criterion = match j.get("criterion").and_then(Json::as_str) {
            Some(s) => Criterion::parse(s)
                .ok_or_else(|| anyhow!("bad criterion {s:?}"))?,
            None => Criterion::None,
        };
        Ok(GenRequest {
            id,
            prefix,
            n_steps,
            criterion,
            noise_scale: j
                .get("noise_scale")
                .and_then(Json::as_f64)
                .unwrap_or(1.0) as f32,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(id as f64)
                as u64,
        })
    }
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub steps_executed: usize,
    pub steps_budget: usize,
    pub halted_early: bool,
    pub latency_ms: f64,
    /// queueing delay before the first denoise step
    pub queue_ms: f64,
    pub final_stats: StepStats,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            (
                "tokens",
                Json::Arr(
                    self.tokens.iter().map(|&t| Json::num(t as f64)).collect(),
                ),
            ),
            ("steps_executed", Json::num(self.steps_executed as f64)),
            ("steps_budget", Json::num(self.steps_budget as f64)),
            ("halted_early", Json::Bool(self.halted_early)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("queue_ms", Json::num(self.queue_ms)),
            ("entropy", Json::num(self.final_stats.entropy as f64)),
            ("kl", Json::num(self.final_stats.kl as f64)),
            ("switches", Json::num(self.final_stats.switches as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenResponse> {
        let get_f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        Ok(GenResponse {
            id: get_f("id")? as u64,
            tokens: j
                .get("tokens")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing tokens"))?
                .iter()
                .filter_map(|x| x.as_f64().map(|v| v as i32))
                .collect(),
            steps_executed: get_f("steps_executed")? as usize,
            steps_budget: get_f("steps_budget")? as usize,
            halted_early: j
                .get("halted_early")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            latency_ms: get_f("latency_ms")?,
            queue_ms: j.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
            final_stats: StepStats {
                entropy: j.get("entropy").and_then(Json::as_f64).unwrap_or(0.0)
                    as f32,
                kl: j.get("kl").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                switches: j
                    .get("switches")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as f32,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let mut r = GenRequest::new(7, 200);
        r.prefix = vec![1, 2, 3];
        r.criterion = Criterion::Kl {
            threshold: 1e-3,
            min_steps: 50,
        };
        r.noise_scale = 0.9;
        let j = r.to_json();
        let back = GenRequest::from_json(&j).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.prefix, vec![1, 2, 3]);
        assert_eq!(back.n_steps, 200);
        assert_eq!(back.criterion, r.criterion);
        assert!((back.noise_scale - 0.9).abs() < 1e-6);
    }

    #[test]
    fn response_json_roundtrip() {
        let resp = GenResponse {
            id: 3,
            tokens: vec![5, 6, 7],
            steps_executed: 120,
            steps_budget: 200,
            halted_early: true,
            latency_ms: 45.5,
            queue_ms: 1.25,
            final_stats: StepStats {
                entropy: 0.5,
                kl: 1e-4,
                switches: 0.0,
                ..Default::default()
            },
        };
        let back =
            GenResponse::from_json(&Json::parse(&resp.to_json().encode())
                .unwrap())
            .unwrap();
        assert_eq!(back.tokens, vec![5, 6, 7]);
        assert!(back.halted_early);
        assert_eq!(back.steps_executed, 120);
        assert!((back.final_stats.entropy - 0.5).abs() < 1e-6);
    }

    #[test]
    fn malformed_request_rejected() {
        assert!(GenRequest::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"bogus"}"#)
                .unwrap()
        )
        .is_err());
    }
}
