//! Request/response types + JSON wire codecs for the serving API.
//!
//! The halting policy travels on the wire as its spec-DSL string under
//! the legacy `criterion` key (`"entropy:0.5"`, `"any(entropy:0.5,
//! patience:20:0)"`, ...).  Serialization goes through the policy's
//! canonical `to_spec()` — there is no second formatting path.
//!
//! Scheduling fields: `priority` ("high" | "normal" | "low", default
//! normal) picks the admission class, `deadline_ms` (optional) bounds the
//! request's total wall-clock time — the scheduler answers with a typed
//! `deadline_exceeded` error if it can't make it — and `family`
//! (optional) routes the request to a worker shard of that model family
//! in a heterogeneous fleet.  Family strings resolve through the open
//! `sampler::registry` (built-ins `"ddlm" | "ssd" | "plaid"` plus any
//! kernel registered at runtime), so the wire is not closed over the
//! `Family` enum.  Requests that omit `family` go to the fleet's
//! default family, so every pre-split client keeps working unchanged;
//! responses echo the serving family.
//!
//! Integer fields (`id`, `seed`, `prefix` / `tokens` entries, step
//! counts) travel as *exact* integers — `util::json` holds integer
//! literals losslessly, so a u64 id above 2^53 round-trips bit-exact
//! instead of silently rounding through f64.  A non-integer entry in
//! `prefix` is a hard parse error (`invalid_request` on the wire), not
//! a silent truncation of the conditioning text.
//!
//! `progress_every: K` (v1 envelope connections only) subscribes the
//! request to throttled per-step `progress` events carrying the paper's
//! completeness estimates ([`StepStats`]: entropy, KL, argmax switches)
//! every K executed steps — see `coordinator::envelope`.

use anyhow::{anyhow, Result};

use crate::halting::{parse_policy, BoxedPolicy, HaltPolicy, NoHalt, StepStats};
use crate::sampler::registry::{self, FamilyId};
use crate::util::json::Json;

/// Admission class: the scheduler drains `High` before `Normal` before
/// `Low` (FIFO within a class).  Pair high-priority traffic with a
/// small-batch worker shard for latency isolation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    pub const COUNT: usize = 3;
    pub const ALL: [Priority; Priority::COUNT] =
        [Priority::High, Priority::Normal, Priority::Low];

    /// Scan/storage index: 0 = high .. 2 = low.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    /// conditioning prefix tokens (empty = unconditional)
    pub prefix: Vec<i32>,
    /// maximum diffusion steps (N_max)
    pub n_steps: usize,
    /// early-exit policy for this request
    pub policy: BoxedPolicy,
    /// initial noise scale (paper Fig 3 / Table 1 knob)
    pub noise_scale: f32,
    pub seed: u64,
    /// admission class (wire field `priority`, default normal)
    pub priority: Priority,
    /// total wall-clock budget from submission; expired requests are
    /// answered with a typed `deadline_exceeded` error (None = no limit)
    pub deadline_ms: Option<f64>,
    /// model family to route to (wire field `family`, resolved through
    /// `sampler::registry`); None = the fleet's default family.  A
    /// family no live worker serves rejects with a typed
    /// `invalid_request` at admission.
    pub family: Option<FamilyId>,
    /// emit a `progress` event every K executed steps (v1 envelope
    /// connections; ignored — never emitted — on legacy one-shot lines)
    pub progress_every: Option<usize>,
    /// attach the per-position `frozen_mask` to this request's progress
    /// events (wire field `frozen_mask: true`).  Default off — frames
    /// for requests that don't ask are byte-identical to pre-token-
    /// halting servers.
    pub frozen_mask: bool,
}

impl GenRequest {
    pub fn new(id: u64, n_steps: usize) -> GenRequest {
        GenRequest {
            id,
            prefix: Vec::new(),
            n_steps,
            policy: Box::new(NoHalt),
            noise_scale: 1.0,
            seed: id,
            priority: Priority::Normal,
            deadline_ms: None,
            family: None,
            progress_every: None,
            frozen_mask: false,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::uint(self.id)),
            (
                "prefix",
                Json::Arr(
                    self.prefix.iter().map(|&t| Json::int(t as i64)).collect(),
                ),
            ),
            ("steps", Json::uint(self.n_steps as u64)),
            ("criterion", Json::str(self.policy.to_spec())),
            ("noise_scale", Json::num(self.noise_scale as f64)),
            ("seed", Json::uint(self.seed)),
            ("priority", Json::str(self.priority.name())),
        ];
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(d)));
        }
        if let Some(f) = self.family {
            fields.push(("family", Json::str(f.name())));
        }
        if let Some(k) = self.progress_every {
            fields.push(("progress_every", Json::uint(k as u64)));
        }
        if self.frozen_mask {
            fields.push(("frozen_mask", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let id = j
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing or non-integer id"))?;
        let n_steps = j
            .get("steps")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("missing or non-integer steps"))?;
        // a malformed prefix entry is a hard rejection: silently
        // dropping it would truncate the conditioning text
        let prefix = match j.get("prefix") {
            None => Vec::new(),
            Some(p) => {
                let arr = p
                    .as_arr()
                    .ok_or_else(|| anyhow!("prefix must be an array"))?;
                let mut out = Vec::with_capacity(arr.len());
                for (i, x) in arr.iter().enumerate() {
                    let tok = x
                        .as_i64()
                        .and_then(|t| i32::try_from(t).ok())
                        .ok_or_else(|| {
                            anyhow!("prefix[{i}] is not an integer token")
                        })?;
                    out.push(tok);
                }
                out
            }
        };
        let policy = match j.get("criterion").and_then(Json::as_str) {
            Some(s) => parse_policy(s)
                .ok_or_else(|| anyhow!("bad criterion {s:?}"))?,
            None => Box::new(NoHalt) as BoxedPolicy,
        };
        let priority = match j.get("priority").and_then(Json::as_str) {
            Some(s) => Priority::parse(s)
                .ok_or_else(|| anyhow!("bad priority {s:?}"))?,
            None => Priority::Normal,
        };
        // unknown family names are rejected at the wire boundary
        // (lookup is the open registry, not the builtin enum); a
        // known-but-unserved family is the scheduler's typed
        // `invalid_request` instead
        let family = match j.get("family").and_then(Json::as_str) {
            Some(s) => Some(
                registry::resolve(s)
                    .ok_or_else(|| anyhow!("unknown family {s:?}"))?,
            ),
            None => None,
        };
        let progress_every = match j.get("progress_every") {
            None => None,
            Some(k) => {
                let k = k.as_usize().ok_or_else(|| {
                    anyhow!("progress_every must be a non-negative integer")
                })?;
                // 0 = no throttle subscription (same as absent)
                (k > 0).then_some(k)
            }
        };
        Ok(GenRequest {
            id,
            prefix,
            n_steps,
            policy,
            noise_scale: j
                .get("noise_scale")
                .and_then(Json::as_f64)
                .unwrap_or(1.0) as f32,
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(id),
            priority,
            deadline_ms: j.get("deadline_ms").and_then(Json::as_f64),
            family,
            progress_every,
            frozen_mask: j
                .get("frozen_mask")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        })
    }
}

/// Mid-generation progress notification for one request — the paper's
/// completeness estimates ([`StepStats`]) sampled every
/// `progress_every` executed steps, streamed to v1 envelope clients so
/// they can act on completeness (e.g. issue a `halt`) while denoising
/// runs.
#[derive(Clone, Debug)]
pub struct ProgressEvent {
    pub id: u64,
    /// steps executed so far (the event fires after this step)
    pub step: usize,
    pub steps_budget: usize,
    pub stats: StepStats,
    /// current decode at this step (prefix positions forced), when the
    /// server attached one — workers do, at the cost of one lazy
    /// `[B, L]` token download shared by every subscribed slot that
    /// step; `None` on frames from servers that don't
    pub tokens: Option<Vec<i32>>,
    /// live steps-to-halt estimate from the fleet predictor (present
    /// only when the engine runs with prediction enabled)
    pub predicted_steps_remaining: Option<usize>,
    /// `step + predicted_steps_remaining` at estimation time
    pub predicted_total_steps: Option<usize>,
    /// per-position freeze state (length L, `true` = pinned by a
    /// token-level policy) — present only when the request opted in
    /// with `frozen_mask: true`; absent frames are byte-identical to
    /// pre-token-halting servers
    pub frozen_mask: Option<Vec<bool>>,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub steps_executed: usize,
    pub steps_budget: usize,
    pub halted_early: bool,
    /// primitive policy reason when `halted_early` (e.g. `"entropy"`)
    pub halt_reason: Option<String>,
    pub latency_ms: f64,
    /// queueing delay before the first denoise step
    pub queue_ms: f64,
    /// model family that served the request (wire field `family`;
    /// absent on responses from pre-multi-family servers)
    pub family: Option<FamilyId>,
    /// steps the predictor still expected at completion (0 on a clean
    /// finish); present only when the engine predicts on the wire
    pub predicted_steps_remaining: Option<usize>,
    /// total steps the predictor expected at admission; compare with
    /// `steps_executed` for the realized prediction error
    pub predicted_total_steps: Option<usize>,
    pub final_stats: StepStats,
}

impl GenResponse {
    /// Zero-step response answered at admission, before any batch slot
    /// or device step: `halt_reason` carries the preflight-resolved
    /// policy primitive (e.g. `fixed:0`), or `None` when the request's
    /// step budget was simply zero (schedule exhausted before the first
    /// step).  Goes through the same metrics bookkeeping
    /// (`Metrics::record_completion`) as worker completions.
    pub fn immediate(req: &GenRequest, halt_reason: Option<&str>) -> GenResponse {
        GenResponse {
            id: req.id,
            tokens: Vec::new(),
            steps_executed: 0,
            steps_budget: req.n_steps,
            halted_early: halt_reason.is_some(),
            halt_reason: halt_reason.map(str::to_string),
            latency_ms: 0.0,
            queue_ms: 0.0,
            family: req.family,
            predicted_steps_remaining: None,
            predicted_total_steps: None,
            final_stats: StepStats::default(),
        }
    }

    /// [`Self::immediate`] for a policy that halted in preflight.
    pub fn preflight(req: &GenRequest, reason: &str) -> GenResponse {
        GenResponse::immediate(req, Some(reason))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::uint(self.id)),
            (
                "tokens",
                Json::Arr(
                    self.tokens.iter().map(|&t| Json::int(t as i64)).collect(),
                ),
            ),
            ("steps_executed", Json::uint(self.steps_executed as u64)),
            ("steps_budget", Json::uint(self.steps_budget as u64)),
            ("halted_early", Json::Bool(self.halted_early)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("queue_ms", Json::num(self.queue_ms)),
            ("entropy", Json::num(self.final_stats.entropy as f64)),
            ("kl", Json::num(self.final_stats.kl as f64)),
            ("switches", Json::num(self.final_stats.switches as f64)),
        ];
        if let Some(reason) = &self.halt_reason {
            fields.push(("halt_reason", Json::str(reason.clone())));
        }
        if let Some(f) = self.family {
            fields.push(("family", Json::str(f.name())));
        }
        if let Some(r) = self.predicted_steps_remaining {
            fields.push(("predicted_steps_remaining", Json::uint(r as u64)));
        }
        if let Some(t) = self.predicted_total_steps {
            fields.push(("predicted_total_steps", Json::uint(t as u64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<GenResponse> {
        let get_f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let get_u = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("missing or non-integer {k}"))
        };
        let mut tokens = Vec::new();
        for (i, x) in j
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing tokens"))?
            .iter()
            .enumerate()
        {
            tokens.push(
                x.as_i64()
                    .and_then(|t| i32::try_from(t).ok())
                    .ok_or_else(|| {
                        anyhow!("tokens[{i}] is not an integer token")
                    })?,
            );
        }
        Ok(GenResponse {
            id: get_u("id")?,
            tokens,
            steps_executed: get_u("steps_executed")? as usize,
            steps_budget: get_u("steps_budget")? as usize,
            halted_early: j
                .get("halted_early")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            halt_reason: j
                .get("halt_reason")
                .and_then(Json::as_str)
                .map(str::to_string),
            latency_ms: get_f("latency_ms")?,
            queue_ms: j.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
            family: j
                .get("family")
                .and_then(Json::as_str)
                .and_then(registry::resolve),
            predicted_steps_remaining: j
                .get("predicted_steps_remaining")
                .and_then(Json::as_usize),
            predicted_total_steps: j
                .get("predicted_total_steps")
                .and_then(Json::as_usize),
            final_stats: StepStats {
                entropy: j.get("entropy").and_then(Json::as_f64).unwrap_or(0.0)
                    as f32,
                kl: j.get("kl").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                switches: j
                    .get("switches")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as f32,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::Family;

    #[test]
    fn request_json_roundtrip() {
        let mut r = GenRequest::new(7, 200);
        r.prefix = vec![1, 2, 3];
        r.policy = parse_policy("kl:0.001:50").unwrap();
        r.noise_scale = 0.9;
        r.priority = Priority::High;
        r.deadline_ms = Some(2500.0);
        r.family = Some(Family::Ssd.into());
        r.progress_every = Some(50);
        let j = r.to_json();
        assert_eq!(
            j.get("criterion").and_then(Json::as_str),
            Some("kl:0.001:50")
        );
        assert_eq!(j.get("family").and_then(Json::as_str), Some("ssd"));
        let back = GenRequest::from_json(&j).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.prefix, vec![1, 2, 3]);
        assert_eq!(back.n_steps, 200);
        assert_eq!(back.policy.to_spec(), r.policy.to_spec());
        assert!((back.noise_scale - 0.9).abs() < 1e-6);
        assert_eq!(back.priority, Priority::High);
        assert_eq!(back.deadline_ms, Some(2500.0));
        assert_eq!(back.family, Some(Family::Ssd.into()));
        assert_eq!(back.progress_every, Some(50));
    }

    #[test]
    fn ids_and_seeds_roundtrip_exactly_beyond_f64_precision() {
        // u64 values above 2^53 must survive the wire bit-exact — the
        // old as_f64 path silently rounded them
        let mut r = GenRequest::new(u64::MAX, 10);
        r.seed = (1u64 << 53) + 1;
        let encoded = r.to_json().encode();
        assert!(encoded.contains("18446744073709551615"), "{encoded}");
        let back =
            GenRequest::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(back.id, u64::MAX);
        assert_eq!(back.seed, (1u64 << 53) + 1);
        // non-integer ids are rejected, not rounded
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1.5,"steps":10}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn malformed_prefix_rejected_not_truncated() {
        // a non-numeric prefix entry must be a hard error — the old
        // filter_map silently dropped it, truncating the conditioning
        for bad in [
            r#"{"id":1,"steps":10,"prefix":[1,"a",3]}"#,
            r#"{"id":1,"steps":10,"prefix":[1,1.5,3]}"#,
            r#"{"id":1,"steps":10,"prefix":[1,null]}"#,
            r#"{"id":1,"steps":10,"prefix":[99999999999]}"#, // > i32::MAX
            r#"{"id":1,"steps":10,"prefix":7}"#,
        ] {
            assert!(
                GenRequest::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted {bad}"
            );
        }
        // well-formed prefixes (including negatives) still parse
        let ok = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"prefix":[3,0,-1]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(ok.prefix, vec![3, 0, -1]);
    }

    #[test]
    fn progress_every_zero_or_absent_disables_events() {
        let none = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(none.progress_every, None);
        assert!(none.to_json().get("progress_every").is_none());
        let zero = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"progress_every":0}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(zero.progress_every, None);
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"progress_every":1.5}"#)
                .unwrap()
        )
        .is_err());
    }

    #[test]
    fn request_scheduling_fields_default_on_legacy_wire() {
        // pre-split clients send neither priority, deadline_ms nor family
        let back = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"none"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(back.priority, Priority::Normal);
        assert_eq!(back.deadline_ms, None);
        assert_eq!(back.family, None);
        assert!(back.to_json().get("deadline_ms").is_none());
        assert!(back.to_json().get("family").is_none());
        // and bad priorities are rejected at the wire boundary
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"priority":"urgent"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn unknown_family_rejected_at_wire_boundary() {
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"family":"gpt"}"#).unwrap()
        )
        .is_err());
        for fam in Family::all() {
            let line =
                format!(r#"{{"id":1,"steps":10,"family":"{}"}}"#, fam.name());
            let back =
                GenRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back.family, Some(fam.into()));
        }
    }

    #[test]
    fn frozen_mask_request_flag_roundtrips_and_defaults_off() {
        // absent on legacy wire, absent when false (default bytes
        // untouched), carried only when the client opts in
        let legacy = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10}"#).unwrap(),
        )
        .unwrap();
        assert!(!legacy.frozen_mask);
        assert!(legacy.to_json().get("frozen_mask").is_none());
        let mut r = GenRequest::new(2, 20);
        r.frozen_mask = true;
        let encoded = r.to_json().encode();
        assert!(encoded.contains(r#""frozen_mask":true"#), "{encoded}");
        let back =
            GenRequest::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert!(back.frozen_mask);
    }

    #[test]
    fn preflight_response_shape() {
        let mut r = GenRequest::new(9, 40);
        r.policy = parse_policy("fixed:0").unwrap();
        let resp = GenResponse::preflight(&r, "fixed");
        assert_eq!(resp.id, 9);
        assert_eq!(resp.steps_executed, 0);
        assert_eq!(resp.steps_budget, 40);
        assert!(resp.halted_early);
        assert_eq!(resp.halt_reason.as_deref(), Some("fixed"));
        assert_eq!(resp.queue_ms, 0.0);
    }

    #[test]
    fn request_roundtrip_preserves_every_policy_variant() {
        // parse -> wire JSON -> parse -> to_spec must be a fixed point
        // for primitives and nested combinators alike
        for spec in [
            "none",
            "entropy:0.25",
            "patience:20:0",
            "patience:20:1.5",
            "kl:0.001:250",
            "fixed:600",
            "norm:0.05:3",
            "klslope:0.02:5",
            "any(entropy:0.5,patience:20:0)",
            "all(kl:0.001:0,fixed:90)",
            "min(50,any(entropy:0.25,klslope:0.02:5))",
            "ema(0.3,norm:0.05:3)",
            "tokstab:4",
            "tokentropy:0.1",
            "any(tokstab:4,entropy:0.25)",
            "min(10,tokentropy:0.05)",
        ] {
            let mut r = GenRequest::new(1, 100);
            r.policy = parse_policy(spec).unwrap();
            let encoded = r.to_json().encode();
            let back =
                GenRequest::from_json(&Json::parse(&encoded).unwrap()).unwrap();
            assert_eq!(back.policy.to_spec(), spec, "wire round-trip of {spec}");
        }
    }

    #[test]
    fn response_json_roundtrip() {
        let resp = GenResponse {
            id: 3,
            tokens: vec![5, 6, 7],
            steps_executed: 120,
            steps_budget: 200,
            halted_early: true,
            halt_reason: Some("kl".to_string()),
            latency_ms: 45.5,
            queue_ms: 1.25,
            family: Some(Family::Plaid.into()),
            predicted_steps_remaining: None,
            predicted_total_steps: None,
            final_stats: StepStats {
                entropy: 0.5,
                kl: 1e-4,
                switches: 0.0,
                ..Default::default()
            },
        };
        let back =
            GenResponse::from_json(&Json::parse(&resp.to_json().encode())
                .unwrap())
            .unwrap();
        assert_eq!(back.tokens, vec![5, 6, 7]);
        assert!(back.halted_early);
        assert_eq!(back.halt_reason.as_deref(), Some("kl"));
        assert_eq!(back.steps_executed, 120);
        assert_eq!(back.family, Some(Family::Plaid.into()));
        assert!((back.final_stats.entropy - 0.5).abs() < 1e-6);
    }

    #[test]
    fn response_without_reason_omits_field() {
        let resp = GenResponse {
            id: 1,
            tokens: vec![],
            steps_executed: 10,
            steps_budget: 10,
            halted_early: false,
            halt_reason: None,
            latency_ms: 1.0,
            queue_ms: 0.0,
            family: None,
            predicted_steps_remaining: None,
            predicted_total_steps: None,
            final_stats: StepStats::default(),
        };
        let j = resp.to_json();
        assert!(j.get("halt_reason").is_none());
        assert!(j.get("family").is_none());
        assert!(j.get("predicted_steps_remaining").is_none());
        assert!(j.get("predicted_total_steps").is_none());
        let back = GenResponse::from_json(&j).unwrap();
        assert_eq!(back.halt_reason, None);
        assert_eq!(back.family, None);
        assert_eq!(back.predicted_steps_remaining, None);
        assert_eq!(back.predicted_total_steps, None);
    }

    #[test]
    fn predicted_fields_roundtrip_when_present() {
        let mut resp = GenResponse::immediate(&GenRequest::new(4, 80), None);
        resp.steps_executed = 60;
        resp.predicted_steps_remaining = Some(0);
        resp.predicted_total_steps = Some(64);
        let encoded = resp.to_json().encode();
        assert!(encoded.contains(r#""predicted_total_steps":64"#), "{encoded}");
        let back =
            GenResponse::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(back.predicted_steps_remaining, Some(0));
        assert_eq!(back.predicted_total_steps, Some(64));
    }

    #[test]
    fn malformed_request_rejected() {
        assert!(GenRequest::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"bogus"}"#)
                .unwrap()
        )
        .is_err());
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"any(entropy:0.5"}"#)
                .unwrap()
        )
        .is_err());
    }
}
