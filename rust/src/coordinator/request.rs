//! Request/response types + JSON wire codecs for the serving API.
//!
//! The halting policy travels on the wire as its spec-DSL string under
//! the legacy `criterion` key (`"entropy:0.5"`, `"any(entropy:0.5,
//! patience:20:0)"`, ...).  Serialization goes through the policy's
//! canonical `to_spec()` — there is no second formatting path.
//!
//! Scheduling fields: `priority` ("high" | "normal" | "low", default
//! normal) picks the admission class, `deadline_ms` (optional) bounds the
//! request's total wall-clock time — the scheduler answers with a typed
//! `deadline_exceeded` error if it can't make it — and `family`
//! (optional: "ddlm" | "ssd" | "plaid") routes the request to a worker
//! shard of that model family in a heterogeneous fleet.  Requests that
//! omit `family` go to the fleet's default family, so every pre-split
//! client keeps working unchanged; responses echo the serving family.

use anyhow::{anyhow, Result};

use crate::halting::{parse_policy, BoxedPolicy, HaltPolicy, NoHalt, StepStats};
use crate::sampler::Family;
use crate::util::json::Json;

/// Admission class: the scheduler drains `High` before `Normal` before
/// `Low` (FIFO within a class).  Pair high-priority traffic with a
/// small-batch worker shard for latency isolation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    pub const COUNT: usize = 3;
    pub const ALL: [Priority; Priority::COUNT] =
        [Priority::High, Priority::Normal, Priority::Low];

    /// Scan/storage index: 0 = high .. 2 = low.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    /// conditioning prefix tokens (empty = unconditional)
    pub prefix: Vec<i32>,
    /// maximum diffusion steps (N_max)
    pub n_steps: usize,
    /// early-exit policy for this request
    pub policy: BoxedPolicy,
    /// initial noise scale (paper Fig 3 / Table 1 knob)
    pub noise_scale: f32,
    pub seed: u64,
    /// admission class (wire field `priority`, default normal)
    pub priority: Priority,
    /// total wall-clock budget from submission; expired requests are
    /// answered with a typed `deadline_exceeded` error (None = no limit)
    pub deadline_ms: Option<f64>,
    /// model family to route to (wire field `family`); None = the
    /// fleet's default family.  A family no live worker serves rejects
    /// with a typed `invalid_request` at admission.
    pub family: Option<Family>,
}

impl GenRequest {
    pub fn new(id: u64, n_steps: usize) -> GenRequest {
        GenRequest {
            id,
            prefix: Vec::new(),
            n_steps,
            policy: Box::new(NoHalt),
            noise_scale: 1.0,
            seed: id,
            priority: Priority::Normal,
            deadline_ms: None,
            family: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            (
                "prefix",
                Json::Arr(
                    self.prefix.iter().map(|&t| Json::num(t as f64)).collect(),
                ),
            ),
            ("steps", Json::num(self.n_steps as f64)),
            ("criterion", Json::str(self.policy.to_spec())),
            ("noise_scale", Json::num(self.noise_scale as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("priority", Json::str(self.priority.name())),
        ];
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(d)));
        }
        if let Some(f) = self.family {
            fields.push(("family", Json::str(f.name())));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing id"))? as u64;
        let n_steps = j
            .get("steps")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("missing steps"))?;
        let prefix = j
            .get("prefix")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_f64().map(|v| v as i32))
                    .collect()
            })
            .unwrap_or_default();
        let policy = match j.get("criterion").and_then(Json::as_str) {
            Some(s) => parse_policy(s)
                .ok_or_else(|| anyhow!("bad criterion {s:?}"))?,
            None => Box::new(NoHalt) as BoxedPolicy,
        };
        let priority = match j.get("priority").and_then(Json::as_str) {
            Some(s) => Priority::parse(s)
                .ok_or_else(|| anyhow!("bad priority {s:?}"))?,
            None => Priority::Normal,
        };
        // unknown family names are rejected at the wire boundary; a
        // known-but-unserved family is the scheduler's typed
        // `invalid_request` instead
        let family = match j.get("family").and_then(Json::as_str) {
            Some(s) => {
                Some(Family::parse(s).ok_or_else(|| anyhow!("bad family {s:?}"))?)
            }
            None => None,
        };
        Ok(GenRequest {
            id,
            prefix,
            n_steps,
            policy,
            noise_scale: j
                .get("noise_scale")
                .and_then(Json::as_f64)
                .unwrap_or(1.0) as f32,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(id as f64)
                as u64,
            priority,
            deadline_ms: j.get("deadline_ms").and_then(Json::as_f64),
            family,
        })
    }
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub steps_executed: usize,
    pub steps_budget: usize,
    pub halted_early: bool,
    /// primitive policy reason when `halted_early` (e.g. `"entropy"`)
    pub halt_reason: Option<String>,
    pub latency_ms: f64,
    /// queueing delay before the first denoise step
    pub queue_ms: f64,
    /// model family that served the request (wire field `family`;
    /// absent on responses from pre-multi-family servers)
    pub family: Option<Family>,
    pub final_stats: StepStats,
}

impl GenResponse {
    /// Zero-step response answered at admission, before any batch slot
    /// or device step: `halt_reason` carries the preflight-resolved
    /// policy primitive (e.g. `fixed:0`), or `None` when the request's
    /// step budget was simply zero (schedule exhausted before the first
    /// step).  Goes through the same metrics bookkeeping
    /// (`Metrics::record_completion`) as worker completions.
    pub fn immediate(req: &GenRequest, halt_reason: Option<&str>) -> GenResponse {
        GenResponse {
            id: req.id,
            tokens: Vec::new(),
            steps_executed: 0,
            steps_budget: req.n_steps,
            halted_early: halt_reason.is_some(),
            halt_reason: halt_reason.map(str::to_string),
            latency_ms: 0.0,
            queue_ms: 0.0,
            family: req.family,
            final_stats: StepStats::default(),
        }
    }

    /// [`Self::immediate`] for a policy that halted in preflight.
    pub fn preflight(req: &GenRequest, reason: &str) -> GenResponse {
        GenResponse::immediate(req, Some(reason))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            (
                "tokens",
                Json::Arr(
                    self.tokens.iter().map(|&t| Json::num(t as f64)).collect(),
                ),
            ),
            ("steps_executed", Json::num(self.steps_executed as f64)),
            ("steps_budget", Json::num(self.steps_budget as f64)),
            ("halted_early", Json::Bool(self.halted_early)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("queue_ms", Json::num(self.queue_ms)),
            ("entropy", Json::num(self.final_stats.entropy as f64)),
            ("kl", Json::num(self.final_stats.kl as f64)),
            ("switches", Json::num(self.final_stats.switches as f64)),
        ];
        if let Some(reason) = &self.halt_reason {
            fields.push(("halt_reason", Json::str(reason.clone())));
        }
        if let Some(f) = self.family {
            fields.push(("family", Json::str(f.name())));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<GenResponse> {
        let get_f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        Ok(GenResponse {
            id: get_f("id")? as u64,
            tokens: j
                .get("tokens")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing tokens"))?
                .iter()
                .filter_map(|x| x.as_f64().map(|v| v as i32))
                .collect(),
            steps_executed: get_f("steps_executed")? as usize,
            steps_budget: get_f("steps_budget")? as usize,
            halted_early: j
                .get("halted_early")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            halt_reason: j
                .get("halt_reason")
                .and_then(Json::as_str)
                .map(str::to_string),
            latency_ms: get_f("latency_ms")?,
            queue_ms: j.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
            family: j
                .get("family")
                .and_then(Json::as_str)
                .and_then(Family::parse),
            final_stats: StepStats {
                entropy: j.get("entropy").and_then(Json::as_f64).unwrap_or(0.0)
                    as f32,
                kl: j.get("kl").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                switches: j
                    .get("switches")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as f32,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let mut r = GenRequest::new(7, 200);
        r.prefix = vec![1, 2, 3];
        r.policy = parse_policy("kl:0.001:50").unwrap();
        r.noise_scale = 0.9;
        r.priority = Priority::High;
        r.deadline_ms = Some(2500.0);
        r.family = Some(Family::Ssd);
        let j = r.to_json();
        assert_eq!(
            j.get("criterion").and_then(Json::as_str),
            Some("kl:0.001:50")
        );
        assert_eq!(j.get("family").and_then(Json::as_str), Some("ssd"));
        let back = GenRequest::from_json(&j).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.prefix, vec![1, 2, 3]);
        assert_eq!(back.n_steps, 200);
        assert_eq!(back.policy.to_spec(), r.policy.to_spec());
        assert!((back.noise_scale - 0.9).abs() < 1e-6);
        assert_eq!(back.priority, Priority::High);
        assert_eq!(back.deadline_ms, Some(2500.0));
        assert_eq!(back.family, Some(Family::Ssd));
    }

    #[test]
    fn request_scheduling_fields_default_on_legacy_wire() {
        // pre-split clients send neither priority, deadline_ms nor family
        let back = GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"none"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(back.priority, Priority::Normal);
        assert_eq!(back.deadline_ms, None);
        assert_eq!(back.family, None);
        assert!(back.to_json().get("deadline_ms").is_none());
        assert!(back.to_json().get("family").is_none());
        // and bad priorities are rejected at the wire boundary
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"priority":"urgent"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn unknown_family_rejected_at_wire_boundary() {
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"family":"gpt"}"#).unwrap()
        )
        .is_err());
        for fam in Family::all() {
            let line =
                format!(r#"{{"id":1,"steps":10,"family":"{}"}}"#, fam.name());
            let back =
                GenRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back.family, Some(fam));
        }
    }

    #[test]
    fn preflight_response_shape() {
        let mut r = GenRequest::new(9, 40);
        r.policy = parse_policy("fixed:0").unwrap();
        let resp = GenResponse::preflight(&r, "fixed");
        assert_eq!(resp.id, 9);
        assert_eq!(resp.steps_executed, 0);
        assert_eq!(resp.steps_budget, 40);
        assert!(resp.halted_early);
        assert_eq!(resp.halt_reason.as_deref(), Some("fixed"));
        assert_eq!(resp.queue_ms, 0.0);
    }

    #[test]
    fn request_roundtrip_preserves_every_policy_variant() {
        // parse -> wire JSON -> parse -> to_spec must be a fixed point
        // for primitives and nested combinators alike
        for spec in [
            "none",
            "entropy:0.25",
            "patience:20:0",
            "patience:20:1.5",
            "kl:0.001:250",
            "fixed:600",
            "norm:0.05:3",
            "klslope:0.02:5",
            "any(entropy:0.5,patience:20:0)",
            "all(kl:0.001:0,fixed:90)",
            "min(50,any(entropy:0.25,klslope:0.02:5))",
            "ema(0.3,norm:0.05:3)",
        ] {
            let mut r = GenRequest::new(1, 100);
            r.policy = parse_policy(spec).unwrap();
            let encoded = r.to_json().encode();
            let back =
                GenRequest::from_json(&Json::parse(&encoded).unwrap()).unwrap();
            assert_eq!(back.policy.to_spec(), spec, "wire round-trip of {spec}");
        }
    }

    #[test]
    fn response_json_roundtrip() {
        let resp = GenResponse {
            id: 3,
            tokens: vec![5, 6, 7],
            steps_executed: 120,
            steps_budget: 200,
            halted_early: true,
            halt_reason: Some("kl".to_string()),
            latency_ms: 45.5,
            queue_ms: 1.25,
            family: Some(Family::Plaid),
            final_stats: StepStats {
                entropy: 0.5,
                kl: 1e-4,
                switches: 0.0,
                ..Default::default()
            },
        };
        let back =
            GenResponse::from_json(&Json::parse(&resp.to_json().encode())
                .unwrap())
            .unwrap();
        assert_eq!(back.tokens, vec![5, 6, 7]);
        assert!(back.halted_early);
        assert_eq!(back.halt_reason.as_deref(), Some("kl"));
        assert_eq!(back.steps_executed, 120);
        assert_eq!(back.family, Some(Family::Plaid));
        assert!((back.final_stats.entropy - 0.5).abs() < 1e-6);
    }

    #[test]
    fn response_without_reason_omits_field() {
        let resp = GenResponse {
            id: 1,
            tokens: vec![],
            steps_executed: 10,
            steps_budget: 10,
            halted_early: false,
            halt_reason: None,
            latency_ms: 1.0,
            queue_ms: 0.0,
            family: None,
            final_stats: StepStats::default(),
        };
        let j = resp.to_json();
        assert!(j.get("halt_reason").is_none());
        assert!(j.get("family").is_none());
        let back = GenResponse::from_json(&j).unwrap();
        assert_eq!(back.halt_reason, None);
        assert_eq!(back.family, None);
    }

    #[test]
    fn malformed_request_rejected() {
        assert!(GenRequest::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"bogus"}"#)
                .unwrap()
        )
        .is_err());
        assert!(GenRequest::from_json(
            &Json::parse(r#"{"id":1,"steps":10,"criterion":"any(entropy:0.5"}"#)
                .unwrap()
        )
        .is_err());
    }
}
