//! Write-ahead admission journal: crash recovery for the serving
//! engine.
//!
//! Every *queued* admission appends an `admit` record carrying the
//! request's full wire params (id, prefix, schedule, policy, seed —
//! [`GenRequest::to_json`]); every terminal resolution appends a
//! `resolve` record carrying the outcome code (`"ok"` or the
//! [`super::ServeError`] taxonomy code).  On restart,
//! [`Journal::open`] replays the log: the requests with an `admit` but
//! no `resolve` are exactly the in-flight set the crash orphaned, and
//! the engine re-admits them deterministically from their recorded
//! params + seed.
//!
//! Record framing (all little-endian):
//!
//! ```text
//! [u32 len] [u32 fnv1a32(payload)] [payload: len bytes of JSON]
//! ```
//!
//! Payloads: `{"ev":"admit","req":{...GenRequest...}}` and
//! `{"ev":"resolve","id":N,"outcome":"ok"|"<code>"}`.
//!
//! A crash can tear the tail: replay stops REPLAYING at the first
//! record whose length is implausible, whose bytes are short, or whose
//! checksum mismatches, keeps COUNTING the sane-looking frames after
//! it (`truncated_records`), truncates the file back to the longest
//! valid prefix (self-heal), and reopens for append.  Appends are
//! fsync-batched ([`FSYNC_BATCH`]); [`Journal::sync`] forces one and
//! drop syncs the tail.  Append failures — real IO errors or an
//! injected `journal_write` fault — are *counted, never propagated*:
//! the serving path must not fail requests because the durability
//! side-channel hiccuped.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::request::GenRequest;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::sync::lock_or_recover;

/// Appends between forced fsyncs.  Batching amortizes the sync cost
/// across a burst; a crash can lose at most this many tail records,
/// which replay treats exactly like a torn tail.
const FSYNC_BATCH: u64 = 32;

/// A record longer than this is treated as tail corruption, not an
/// allocation request (a torn length prefix can read as gigabytes).
const MAX_RECORD: u32 = 1 << 20;

/// Record header: u32 payload length + u32 FNV-1a checksum.
const HEADER: usize = 8;

fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// What [`Journal::open`] recovered from an existing log.
pub struct Replay {
    /// admitted-but-unresolved requests, in admission order — the
    /// exact set a crash orphaned; the engine re-admits them
    pub incomplete: Vec<GenRequest>,
    /// valid records replayed
    pub records: u64,
    /// sane-looking frames discarded past the first invalid record
    /// (torn tail / corrupted checksum) — the
    /// `journal_truncated_records` counter
    pub truncated_records: u64,
}

struct Inner {
    file: Option<File>,
    /// appends since the last fsync
    unsynced: u64,
}

/// Append-only write-ahead log handle, shared (`Arc`) between the
/// scheduler (admits/resolves) and the engine (metrics, sync).
pub struct Journal {
    path: PathBuf,
    inner: Mutex<Inner>,
    /// sealed ⇒ writes stop (crash simulation for tests/bench; a
    /// sealed journal behaves like the process already died)
    sealed: AtomicBool,
    records: AtomicU64,
    bytes: AtomicU64,
    truncated: AtomicU64,
    replayed: AtomicU64,
    write_failures: AtomicU64,
}

/// One record scanned from disk.
enum Scanned {
    /// valid payload (consumed `HEADER + len` bytes)
    Ok(Vec<u8>),
    /// invalid here, but the frame's claimed extent stays in-bounds —
    /// count it and keep scanning frames for the truncated tally
    Corrupt(usize),
    /// nothing sane at this offset — stop counting
    End,
}

fn scan_record(buf: &[u8], at: usize) -> Scanned {
    let Some(head) = buf.get(at..at + HEADER) else {
        return Scanned::End;
    };
    // slices are HEADER bytes by construction
    let len = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    let sum = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if len > MAX_RECORD {
        return Scanned::End;
    }
    let end = at + HEADER + len as usize;
    let Some(payload) = buf.get(at + HEADER..end) else {
        // torn tail: the frame claims more bytes than the file holds
        return Scanned::Corrupt(buf.len() - at);
    };
    if fnv1a32(payload) != sum {
        return Scanned::Corrupt(HEADER + len as usize);
    }
    Scanned::Ok(payload.to_vec())
}

fn parse_payload(
    payload: &[u8],
    admits: &mut Vec<GenRequest>,
) -> Result<()> {
    let text = std::str::from_utf8(payload)
        .context("journal payload is not UTF-8")?;
    let j = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("journal payload: {e}"))?;
    match j.get("ev").and_then(Json::as_str) {
        Some("admit") => {
            let req = j
                .get("req")
                .context("admit record without req")
                .and_then(GenRequest::from_json)?;
            // re-admission of a retried/replayed id supersedes the
            // older record for the same id
            admits.retain(|r| r.id != req.id);
            admits.push(req);
        }
        Some("resolve") => {
            let id = j
                .get("id")
                .and_then(Json::as_u64)
                .context("resolve record without id")?;
            admits.retain(|r| r.id != id);
        }
        _ => anyhow::bail!("journal record with unknown ev"),
    }
    Ok(())
}

impl Journal {
    /// Open (or create) the journal at `path`, replay it, self-heal
    /// the tail, and return the handle plus what was recovered.
    pub fn open(path: impl AsRef<Path>) -> Result<(Journal, Replay)> {
        let path = path.as_ref().to_path_buf();
        let mut buf = Vec::new();
        let mut file = OpenOptions::new()
            .read(true)
            .append(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("open journal {}", path.display()))?;
        file.read_to_end(&mut buf)
            .with_context(|| format!("read journal {}", path.display()))?;

        let mut admits: Vec<GenRequest> = Vec::new();
        let mut records = 0u64;
        let mut truncated = 0u64;
        let mut at = 0usize;
        let mut valid_end = 0usize;
        let mut healthy = true;
        while at < buf.len() {
            match scan_record(&buf, at) {
                Scanned::Ok(payload) => {
                    let parsed = healthy
                        && parse_payload(&payload, &mut admits).is_ok();
                    if parsed {
                        records += 1;
                        at += HEADER + payload.len();
                        valid_end = at;
                    } else {
                        // a well-framed record with a bad payload (or
                        // any frame past the first bad one) counts as
                        // truncated, not replayed
                        healthy = false;
                        truncated += 1;
                        at += HEADER + payload.len();
                    }
                }
                Scanned::Corrupt(span) => {
                    healthy = false;
                    truncated += 1;
                    at += span;
                }
                Scanned::End => break,
            }
        }

        // self-heal: drop everything past the longest valid prefix so
        // the next append starts on a record boundary
        if valid_end < buf.len() {
            file.set_len(valid_end as u64).with_context(|| {
                format!("truncate journal {}", path.display())
            })?;
            file.seek(SeekFrom::End(0))
                .with_context(|| format!("seek journal {}", path.display()))?;
        }

        let journal = Journal {
            path,
            inner: Mutex::new(Inner { file: Some(file), unsynced: 0 }),
            sealed: AtomicBool::new(false),
            records: AtomicU64::new(records),
            bytes: AtomicU64::new(valid_end as u64),
            truncated: AtomicU64::new(truncated),
            replayed: AtomicU64::new(0),
            write_failures: AtomicU64::new(0),
        };
        let replay = Replay { incomplete: admits, records, truncated_records: truncated };
        Ok((journal, replay))
    }

    /// Append one record.  Best-effort by design: an IO error (or an
    /// injected `journal_write` fault) is counted and logged, never
    /// propagated — the serving path must not fail a request because
    /// the durability side-channel did.
    fn append(&self, payload: &Json) {
        if self.sealed.load(Ordering::Acquire) {
            return;
        }
        let text = payload.encode();
        let bytes = text.as_bytes();
        if bytes.len() as u64 > MAX_RECORD as u64 {
            self.write_failures.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!(
                "journal: record of {} bytes exceeds the frame bound, \
                 dropped",
                bytes.len()
            );
            return;
        }
        let mut frame = Vec::with_capacity(HEADER + bytes.len());
        frame.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        frame.extend_from_slice(&fnv1a32(bytes).to_le_bytes());
        frame.extend_from_slice(bytes);

        let mut inner = lock_or_recover(&self.inner);
        let injected = fault::check("journal_write").is_some();
        let wrote = if injected {
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "injected journal_write fault",
            ))
        } else if let Some(f) = inner.file.as_mut() {
            f.write_all(&frame)
        } else {
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "journal file unavailable",
            ))
        };
        match wrote {
            Ok(()) => {
                self.records.fetch_add(1, Ordering::Relaxed);
                self.bytes
                    .fetch_add(frame.len() as u64, Ordering::Relaxed);
                inner.unsynced += 1;
                if inner.unsynced >= FSYNC_BATCH {
                    inner.unsynced = 0;
                    if let Some(f) = inner.file.as_mut() {
                        let _ = f.sync_data();
                    }
                }
            }
            Err(e) => {
                self.write_failures.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "journal: append to {} failed: {e}",
                    self.path.display()
                );
            }
        }
    }

    /// Record a queued admission (the request's full wire params — the
    /// replay side re-admits from exactly these).
    pub fn admit(&self, req: &GenRequest) {
        self.admit_json(req.to_json());
    }

    /// [`Self::admit`] from a pre-serialized request: the scheduler
    /// encodes outside its state lock and appends inside it, so the
    /// admit record always precedes the request's resolve.
    pub fn admit_json(&self, req_json: Json) {
        self.append(&Json::obj(vec![
            ("ev", Json::str("admit")),
            ("req", req_json),
        ]));
    }

    /// Record a terminal resolution: `outcome` is `"ok"` or the
    /// [`super::ServeError`] taxonomy code.
    pub fn resolve(&self, id: u64, outcome: &str) {
        self.append(&Json::obj(vec![
            ("ev", Json::str("resolve")),
            ("id", Json::uint(id)),
            ("outcome", Json::str(outcome)),
        ]));
    }

    /// Force the batched tail to disk.
    pub fn sync(&self) {
        let mut inner = lock_or_recover(&self.inner);
        inner.unsynced = 0;
        if let Some(f) = inner.file.as_mut() {
            let _ = f.sync_data();
        }
    }

    /// Stop all future writes, leaving the on-disk state as-is — the
    /// crash-simulation hook for chaos tests and the recovery bench (a
    /// sealed journal looks exactly like the process died here).
    pub fn seal(&self) {
        self.sealed.store(true, Ordering::Release);
        let mut inner = lock_or_recover(&self.inner);
        inner.file = None;
    }

    /// Note how many replayed requests the engine re-admitted (the
    /// `journal_replayed` metrics key).
    pub fn note_replayed(&self, n: u64) {
        self.replayed.store(n, Ordering::Relaxed);
    }

    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn truncated_records(&self) -> u64 {
        self.truncated.load(Ordering::Relaxed)
    }

    pub fn replayed(&self) -> u64 {
        self.replayed.load(Ordering::Relaxed)
    }

    pub fn write_failures(&self) -> u64 {
        self.write_failures.load(Ordering::Relaxed)
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        let mut inner = lock_or_recover(&self.inner);
        if let Some(f) = inner.file.as_mut() {
            let _ = f.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("repro_journal_{name}_{}", std::process::id()));
        p
    }

    fn req(id: u64) -> GenRequest {
        let mut r = GenRequest::new(id, 8);
        r.prefix = vec![1, 2, 3];
        r
    }

    #[test]
    fn round_trip_replays_incomplete_set() {
        let path = tmp("round_trip");
        let _ = std::fs::remove_file(&path);
        {
            let (j, r) = Journal::open(&path).unwrap();
            assert_eq!(r.records, 0);
            assert!(r.incomplete.is_empty());
            j.admit(&req(1));
            j.admit(&req(2));
            j.admit(&req(3));
            j.resolve(2, "ok");
            j.sync();
        }
        let (_j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.records, 4);
        assert_eq!(r.truncated_records, 0);
        let ids: Vec<u64> = r.incomplete.iter().map(|q| q.id).collect();
        assert_eq!(ids, vec![1, 3]);
        assert_eq!(r.incomplete[0].n_steps, 8);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_replays_longest_valid_prefix() {
        let path = tmp("torn_tail");
        let _ = std::fs::remove_file(&path);
        {
            let (j, _) = Journal::open(&path).unwrap();
            j.admit(&req(1));
            j.admit(&req(2));
            j.sync();
        }
        // tear the last record: chop 5 bytes off the file
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.records, 1);
        assert_eq!(r.truncated_records, 1);
        assert_eq!(r.incomplete.len(), 1);
        assert_eq!(r.incomplete[0].id, 1);
        // self-healed: appends continue cleanly after the truncation
        j.admit(&req(3));
        j.sync();
        drop(j);
        let (_j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.truncated_records, 0);
        let ids: Vec<u64> = r.incomplete.iter().map(|q| q.id).collect();
        assert_eq!(ids, vec![1, 3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_checksum_mid_file_counts_tail_frames() {
        let path = tmp("corrupt_mid");
        let _ = std::fs::remove_file(&path);
        let (ends, total) = {
            let (j, _) = Journal::open(&path).unwrap();
            let mut ends = Vec::new();
            for id in 1..=5 {
                j.admit(&req(id));
                j.sync();
                ends.push(std::fs::metadata(&path).unwrap().len());
            }
            (ends, std::fs::metadata(&path).unwrap().len())
        };
        // flip one payload byte inside record 3 (frames 3..5 become
        // unreplayable: 3 corrupt, 4-5 past the corruption)
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = ends[2] as usize - 1;
        bytes[idx] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(bytes.len() as u64, total);
        let (_j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.records, 2);
        assert_eq!(r.truncated_records, 3);
        let ids: Vec<u64> = r.incomplete.iter().map(|q| q.id).collect();
        assert_eq!(ids, vec![1, 2]);
        // healed file holds exactly the valid prefix
        assert_eq!(std::fs::metadata(&path).unwrap().len(), ends[1]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_journal_is_clean() {
        let path = tmp("empty");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, b"").unwrap();
        let (_j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.records, 0);
        assert_eq!(r.truncated_records, 0);
        assert!(r.incomplete.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn injected_write_fault_is_counted_not_propagated() {
        let _g = crate::util::fault::test_serial();
        let path = tmp("write_fault");
        let _ = std::fs::remove_file(&path);
        crate::util::fault::install("journal_write@1:fail").unwrap();
        let (j, _) = Journal::open(&path).unwrap();
        j.admit(&req(1)); // hit 0: lands
        j.admit(&req(2)); // hit 1: injected failure, swallowed
        j.admit(&req(3)); // hit 2: lands
        j.sync();
        assert_eq!(j.write_failures(), 1);
        crate::util::fault::clear();
        drop(j);
        let (_j, r) = Journal::open(&path).unwrap();
        let ids: Vec<u64> = r.incomplete.iter().map(|q| q.id).collect();
        assert_eq!(ids, vec![1, 3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sealed_journal_stops_recording() {
        let path = tmp("sealed");
        let _ = std::fs::remove_file(&path);
        let (j, _) = Journal::open(&path).unwrap();
        j.admit(&req(1));
        j.sync();
        j.seal();
        j.resolve(1, "ok"); // post-seal: unrecorded, like a crash
        drop(j);
        let (_j, r) = Journal::open(&path).unwrap();
        assert_eq!(r.incomplete.len(), 1);
        assert_eq!(r.incomplete[0].id, 1);
        let _ = std::fs::remove_file(&path);
    }
}
