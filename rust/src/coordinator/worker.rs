//! A worker shard: one OS thread owning one PJRT runtime and one batched
//! generation `Session`, pulling work from the shared [`Scheduler`].
//!
//! The xla handles are not `Send`, so each worker constructs its own
//! `Runtime` and compiles its own step artifact.  Workers may bind
//! different compiled batch sizes *and different model families*: a
//! `(Ddlm, 1)` worker turns individual ddlm requests around quickly
//! (latency shard) while a `(Ssd, 8)` worker soaks ssd throughput
//! traffic — the scheduler routes each request to a worker of its
//! family and its priority classes decide what every worker picks up
//! next (high before normal before low), so one fleet serves a
//! heterogeneous model mix without separate deployments.
//!
//! Per loop iteration a worker: admits queued requests into free slots
//! (continuous batching — slots freed by an early halt are refilled
//! mid-schedule), aborts slots whose request was cancelled or whose
//! deadline expired, *finalizes* slots whose request was gracefully
//! halted by the client (a normal completion carrying the current x0
//! decode and `halt_reason:"client"`), then advances all active slots
//! with one device call — emitting a throttled [`ProgressEvent`] to any
//! subscribed slot every `progress_every` executed steps.  Every
//! completed request goes through the shared
//! [`Metrics::record_completion`] bookkeeping.
//!
//! Families are registry ids ([`FamilyId`]): the worker resolves its
//! kernel through the open `sampler::registry`, and loads artifacts /
//! checkpoints under the kernel's `artifact_prefix()` — so a kernel
//! registered at runtime can serve on existing compiled artifacts.

use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{GenResponse, ProgressEvent};
use super::scheduler::{Flagged, IdleWait, QueuedReq, Scheduler, ServeError};
use crate::halting::{BoxedPolicy, Decision, NoHalt};
use crate::log_info;
use crate::models::store::ParamStore;
use crate::predictor::{
    bucket_for, slope_bucket_for, Estimator, N_BUCKETS, N_SLOPE_BUCKETS,
};
use crate::runtime::Runtime;
use crate::sampler::{FamilyId, Session, SlotRequest};

pub struct WorkerConfig {
    pub id: usize,
    pub artifact_dir: String,
    pub family: FamilyId,
    /// requested batch size; resolved to the nearest compiled artifact
    pub batch: usize,
    /// trained checkpoint (PBIN); falls back to init params when None
    pub checkpoint: Option<String>,
    /// schedule envelope this shard serves (engine-level default or a
    /// per-family override)
    pub t_max: f32,
    pub t_min: f32,
    /// shared fleet estimator: this worker feeds it per-step latency
    /// and per-completion halt-step observations, and reads live
    /// remaining-steps estimates from it (None = no predictor)
    pub predictor: Option<Arc<Estimator>>,
    /// emit `predicted_steps_remaining` / `predicted_total_steps` on
    /// progress and done frames (the wire-visible predictor gate)
    pub predict_wire: bool,
}

struct Running {
    q: QueuedReq,
    /// this slot's live policy (cloned from the request and reset on
    /// admission; the request keeps the pristine copy for its spec)
    policy: BoxedPolicy,
    started: Instant,
    /// step at which this generation *first* entered each entropy
    /// bucket — the estimator's conditioned-EMA training signal
    bucket_entry: [Option<usize>; N_BUCKETS],
    /// step at which this generation first entered each KL-slope
    /// bucket (the estimator's second conditioning feature)
    slope_entry: [Option<usize>; N_SLOPE_BUCKETS],
    /// previous step's KL stat — the per-slot slope signal is the
    /// one-step difference `kl - prev_kl`
    prev_kl: Option<f32>,
    /// positions freeze-pinned by this request's policy so far
    tokens_frozen: u64,
    /// token-steps spent stepping positions that were already frozen
    /// (the numerator of `frozen_step_fraction`)
    frozen_token_steps: u64,
    /// token-level steps saved: at each freeze, newly-frozen positions
    /// × the slot's remaining step budget
    token_steps_saved: u64,
    /// latest live re-estimate `(remaining, total)` for the wire
    last_prediction: Option<(usize, usize)>,
}

/// Spawn the worker thread.  It exits when the scheduler reports
/// shutdown with a drained queue; startup errors (missing artifacts,
/// bad checkpoint) surface through the join handle.
pub fn spawn(
    cfg: WorkerConfig,
    sched: Arc<Scheduler>,
    metrics: Arc<Mutex<Metrics>>,
) -> JoinHandle<Result<()>> {
    std::thread::spawn(move || {
        // worker_down must run even if run_worker panics: a stale
        // workers_live would keep the scheduler admitting requests
        // nobody will ever serve (clients hang instead of getting the
        // typed `unavailable` failover), so tie it to a Drop guard
        struct Down(Arc<Scheduler>, usize);
        impl Drop for Down {
            fn drop(&mut self) {
                self.0.worker_down(self.1);
            }
        }
        let _down = Down(sched.clone(), cfg.id);
        run_worker(&cfg, &sched, &metrics)
    })
}

fn run_worker(
    cfg: &WorkerConfig,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
) -> Result<()> {
    let rt = Runtime::new(&cfg.artifact_dir)?;
    let m = rt.manifest.model.clone();
    // artifacts and checkpoints live under the kernel's artifact
    // prefix — for built-ins that is the family name, for registered
    // wrapper kernels the family whose compiled artifacts they reuse
    let prefix = cfg.family.kernel().artifact_prefix();
    let store = match &cfg.checkpoint {
        Some(path) => ParamStore::load(path, prefix)?,
        None => ParamStore::load_init(&cfg.artifact_dir, prefix)?,
    };
    // artifacts are compiled for fixed batch sizes; resolve the nearest
    // available one (>= requested, else the largest)
    let batch =
        rt.manifest.resolve_step_batch(prefix, m.seq_len, cfg.batch)?;
    let mut session =
        Session::new(&rt, cfg.family, Rc::new(store), batch, m.seq_len)?;
    log_info!(
        "worker {} up: family={} batch={} (requested {}) seq_len={} \
         resident={}",
        cfg.id,
        cfg.family.name(),
        batch,
        cfg.batch,
        m.seq_len,
        session.resident()
    );
    metrics.lock().unwrap().slots_total = batch as u64;

    let mut running: Vec<Option<Running>> = (0..batch).map(|_| None).collect();
    // extensible policy code runs inside the step loop; if it (or a
    // session invariant) panics, fail this worker's in-flight requests
    // over with a typed error before the unwind continues — dropping
    // their reply channels would surface to clients as an untyped
    // "reply channel closed" instead of the documented `unavailable`
    let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || step_loop(cfg, sched, metrics, &mut session, &mut running),
    ));
    match stepped {
        Ok(out) => out?,
        Err(panic) => {
            for r in running.iter_mut().filter_map(Option::take) {
                sched.finish(r.q.req.id);
                let _ = r.q.reply.send(Err(ServeError::Unavailable));
            }
            std::panic::resume_unwind(panic);
        }
    }
    let (completed, ratio) = {
        let wm = metrics.lock().unwrap();
        (wm.requests_completed, wm.step_saving_ratio())
    };
    log_info!(
        "worker {} down: {} completed, saving ratio {:.3}",
        cfg.id,
        completed,
        ratio
    );
    Ok(())
}

/// The worker's serve loop: admit / reap / step / account, until the
/// scheduler reports shutdown with a drained queue.
fn step_loop(
    cfg: &WorkerConfig,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
    session: &mut Session,
    running: &mut [Option<Running>],
) -> Result<()> {
    let batch = session.batch;
    // reusable sweep scratch (occupied slots, their request ids, and
    // the scheduler's verdicts) — the hot loop allocates nothing per
    // iteration for the flag sweep
    let mut flag_slots: Vec<usize> = Vec::with_capacity(batch);
    let mut flag_ids: Vec<u64> = Vec::with_capacity(batch);
    let mut flags: Vec<Option<Flagged>> = Vec::with_capacity(batch);
    loop {
        // 0) fully idle: sleep until work our family can serve arrives
        //    or shutdown drains us
        if running.iter().all(Option::is_none) {
            match sched.wait_for_work(cfg.id) {
                IdleWait::Work => {}
                IdleWait::Exit => break,
            }
        }

        // 1) admit queued requests into free slots (continuous
        //    batching; the scheduler only hands us our own family's
        //    requests).  Requests this session can't hold are rejected
        //    with a typed error, never a panic — admission normally
        //    filters them, but the scheduler may not know our seq_len
        //    (manifest read failed) and must not be trusted with it
        'admit: for slot in 0..batch {
            while running[slot].is_none() {
                let Some(q) = sched.next_for(cfg.id) else { break 'admit };
                // park the request in its slot BEFORE running any
                // extensible policy code (clone/reset) or session
                // setup: if one of those panics, the catch_unwind
                // failover still sees this request and answers it with
                // a typed error instead of dropping its reply channel
                running[slot] = Some(Running {
                    policy: Box::new(NoHalt),
                    started: Instant::now(),
                    bucket_entry: [None; N_BUCKETS],
                    slope_entry: [None; N_SLOPE_BUCKETS],
                    prev_kl: None,
                    tokens_frozen: 0,
                    frozen_token_steps: 0,
                    token_steps_saved: 0,
                    last_prediction: None,
                    q,
                });
                let r = running[slot].as_mut().unwrap();
                let mut policy = r.q.req.policy.clone();
                policy.reset();
                r.policy = policy;
                let reset = session.reset_slot(
                    slot,
                    &SlotRequest::new(
                        r.q.req.seed,
                        r.q.req.n_steps,
                        cfg.t_max,
                        cfg.t_min,
                    )
                    .noise(r.q.req.noise_scale)
                    .prefix(&r.q.req.prefix),
                );
                if let Err(e) = reset {
                    // typed backstop (overlong prefix / zero-step
                    // budget the scheduler should have filtered): the
                    // reset validated-then-left the slot untouched, so
                    // just answer and move on
                    let r = running[slot].take().unwrap();
                    log_info!(
                        "worker {} rejected request {}: {e}",
                        cfg.id,
                        r.q.req.id
                    );
                    sched.finish(r.q.req.id);
                    metrics.lock().unwrap().rejected_invalid += 1;
                    let _ = r.q.reply.send(Err(ServeError::InvalidRequest));
                    continue;
                }
            }
        }

        // 2) sweep expired queued deadlines (so a saturated fleet still
        //    answers them within one step latency), then abort slots
        //    whose request was cancelled or whose deadline expired
        //    mid-schedule, and gracefully finalize slots whose request
        //    the client halted (cancel outranks halt)
        sched.reap_expired();
        let now = Instant::now();
        enum Sweep {
            Abort(ServeError),
            Finalize,
        }
        // ONE scheduler lock answers the cancel/halt flags for the
        // whole sweep (the per-slot check cost one lock per occupied
        // slot per iteration); precedence: cancel > deadline > halt
        flag_slots.clear();
        flag_ids.clear();
        for (slot, r) in running.iter().enumerate() {
            if let Some(r) = r {
                flag_slots.push(slot);
                flag_ids.push(r.q.req.id);
            }
        }
        sched.flagged_sweep_into(&flag_ids, &mut flags);
        for (&slot, &flagged) in flag_slots.iter().zip(&flags) {
            let Some(r) = running[slot].as_ref() else { continue };
            let action = if flagged == Some(Flagged::Cancel) {
                Some(Sweep::Abort(ServeError::Cancelled))
            } else if r.q.deadline.is_some_and(|d| now >= d) {
                Some(Sweep::Abort(ServeError::DeadlineExceeded))
            } else if flagged == Some(Flagged::Halt) {
                Some(Sweep::Finalize)
            } else {
                None
            };
            match action {
                None => {}
                Some(Sweep::Abort(err)) => {
                    let r = running[slot].take().unwrap();
                    sched.finish(r.q.req.id);
                    {
                        let mut wm = metrics.lock().unwrap();
                        match err {
                            ServeError::Cancelled => wm.cancelled += 1,
                            _ => wm.deadline_exceeded += 1,
                        }
                        // steps burned before the abort still count —
                        // in the family lane too, so per-family steps
                        // reconcile with the fleet total
                        wm.record_aborted_steps(
                            cfg.family,
                            session.slots[slot].step as u64,
                        );
                    }
                    session.release_slot(slot);
                    let _ = r.q.reply.send(Err(err));
                }
                Some(Sweep::Finalize) => {
                    // graceful client halt: a NORMAL completion with
                    // the slot's current x0 decode — the wire-visible
                    // form of the paper's early exit, so it shares the
                    // one completion bookkeeping path
                    let r = running[slot].take().unwrap();
                    let steps = session.slots[slot].step;
                    let tokens = session.slot_output(slot);
                    if let Some(e) = session.take_deferred_err() {
                        // the lazy decode download failed: this
                        // completion has no trustworthy tokens — fail
                        // THIS request with a typed internal error
                        // instead of poisoning the whole batch at the
                        // next step()
                        abort_download_failed(
                            cfg, sched, metrics, session, slot, r, steps, &e,
                        );
                        continue;
                    }
                    let resp = GenResponse {
                        id: r.q.req.id,
                        tokens,
                        steps_executed: steps,
                        steps_budget: r.q.req.n_steps,
                        halted_early: true,
                        halt_reason: Some("client".to_string()),
                        latency_ms: r.started.elapsed().as_secs_f64() * 1e3,
                        queue_ms: (r.started - r.q.submitted).as_secs_f64()
                            * 1e3,
                        family: Some(cfg.family),
                        predicted_steps_remaining: if cfg.predict_wire {
                            r.last_prediction.map(|(rem, _)| rem)
                        } else {
                            None
                        },
                        predicted_total_steps: if cfg.predict_wire {
                            r.q.predicted_steps
                        } else {
                            None
                        },
                        final_stats: session.slots[slot].last_stats,
                    };
                    if let Some(est) = &cfg.predictor {
                        est.observe_completion_full(
                            cfg.family,
                            steps,
                            &visited_buckets(&r.bucket_entry),
                            &visited_slope(&r.slope_entry),
                        );
                    }
                    sched.finish(resp.id);
                    {
                        let mut wm = metrics.lock().unwrap();
                        wm.record_completion(
                            &resp,
                            r.q.req.priority,
                            cfg.family,
                        );
                        if r.tokens_frozen > 0 {
                            wm.record_token_halting(
                                cfg.family,
                                r.tokens_frozen,
                                r.frozen_token_steps,
                                r.token_steps_saved,
                                (steps * session.seq_len) as u64,
                            );
                        }
                    }
                    session.release_slot(slot);
                    let _ = r.q.reply.send(Ok(resp));
                }
            }
        }

        // 3) one batched device step; responses are *collected* first —
        //    bookkeeping commits under the single metrics guard below,
        //    then the replies go out on the wire
        let stepped = running.iter().any(Option::is_some);
        let mut done: Vec<(GenResponse, Running)> = Vec::new();
        if stepped {
            let step_started = Instant::now();
            let stats = match session.step() {
                Ok(stats) => stats,
                Err(e) => {
                    // device failure: fail this worker's in-flight
                    // requests over with a typed error (and release
                    // their scheduler state) before surfacing the error
                    for r in running.iter_mut().filter_map(Option::take) {
                        sched.finish(r.q.req.id);
                        let _ =
                            r.q.reply.send(Err(ServeError::Unavailable));
                    }
                    return Err(e);
                }
            };
            // the batched step latency is the admission gate's
            // wall-time basis: one observation per device call
            if let Some(est) = &cfg.predictor {
                est.observe_step_latency(
                    cfg.family,
                    step_started.elapsed().as_secs_f64() * 1e3,
                );
            }
            for slot in 0..batch {
                let Some(st) = stats[slot] else { continue };
                let Some(r) = running[slot].as_mut() else { continue };
                let executed = session.slots[slot].step;
                // token-steps the step that just ran spent on already-
                // pinned positions (numerator of frozen_step_fraction);
                // counted BEFORE this observe's freeze verdict applies
                r.frozen_token_steps += session.frozen_count(slot) as u64;
                // token-level observe when per-position lanes are live
                // (fused format-3 stats on a kernel that opts in); the
                // observe_tokens default makes sequence-level policies
                // behave identically on both call paths
                let decision = match session.slot_token_lanes(slot) {
                    Some(lanes) => {
                        r.policy.observe_tokens(executed - 1, &st, &lanes)
                    }
                    None => r.policy.observe(executed - 1, &st),
                };
                // apply a freeze verdict: the session clamps the masked
                // positions on-device like a dynamically-grown prefix;
                // a slot with every position pinned is done and
                // completes like a policy halt, reason "all_frozen"
                let mut all_frozen = false;
                if let Decision::Freeze { mask } = &decision {
                    match session.freeze_positions(slot, mask) {
                        Ok(newly) => {
                            if newly > 0 {
                                r.tokens_frozen += newly as u64;
                                r.token_steps_saved += newly as u64
                                    * r.q.req.n_steps.saturating_sub(executed)
                                        as u64;
                            }
                            all_frozen = session.fully_frozen(slot);
                        }
                        Err(e) => {
                            // freezing syncs the decode; a failed
                            // download fails THIS request, typed
                            let r = running[slot].take().unwrap();
                            abort_download_failed(
                                cfg,
                                sched,
                                metrics,
                                session,
                                slot,
                                r,
                                executed,
                                &e.to_string(),
                            );
                            continue;
                        }
                    }
                }
                let halted = decision.halted() || all_frozen;
                let exhausted = session.slot_exhausted(slot);
                // predictor plumbing: remember when this generation
                // first entered each entropy and KL-slope bucket (the
                // estimator's training signal), and — when prediction
                // is on the wire — refresh the live remaining-steps
                // estimate with the slot's slope and frozen-fraction
                // completeness features
                let kl_slope = r.prev_kl.map(|p| st.kl - p);
                r.prev_kl = Some(st.kl);
                if let Some(est) = &cfg.predictor {
                    let b = bucket_for(&st);
                    if r.bucket_entry[b].is_none() {
                        r.bucket_entry[b] = Some(executed);
                    }
                    if let Some(d) = kl_slope {
                        let sb = slope_bucket_for(d);
                        if r.slope_entry[sb].is_none() {
                            r.slope_entry[sb] = Some(executed);
                        }
                    }
                    if cfg.predict_wire {
                        let p = est.predict_remaining_with(
                            cfg.family,
                            &st,
                            kl_slope,
                            session.frozen_fraction(slot),
                            executed,
                            r.q.req.n_steps,
                        );
                        r.last_prediction =
                            Some((p.steps, executed + p.steps));
                    }
                }
                // throttled progress fan-out: subscribed requests get
                // the paper's completeness estimates — and the current
                // decode (one lazy [B,L] token download shared by every
                // subscribed slot this step) — every `progress_every`
                // executed steps (terminal steps are reported by the
                // done frame instead).  A dead subscriber is dropped on
                // the first failed send so the hot loop never retries
                // into a closed channel.
                let mut download_err: Option<String> = None;
                if !(halted || exhausted) {
                    let every = r.q.req.progress_every.unwrap_or(0);
                    if every > 0
                        && executed % every == 0
                        && r.q.progress.is_some()
                    {
                        let toks = session.slot_output(slot);
                        match session.take_deferred_err() {
                            Some(e) => download_err = Some(e),
                            None => {
                                let ev = ProgressEvent {
                                    id: r.q.req.id,
                                    step: executed,
                                    steps_budget: r.q.req.n_steps,
                                    stats: st,
                                    tokens: Some(toks),
                                    predicted_steps_remaining: r
                                        .last_prediction
                                        .map(|(rem, _)| rem),
                                    predicted_total_steps: r
                                        .last_prediction
                                        .map(|(_, tot)| tot),
                                    // per-position freeze state, only
                                    // for requests that asked for it —
                                    // default wire bytes are untouched
                                    frozen_mask: if r.q.req.frozen_mask {
                                        Some(
                                            session.slot_frozen_mask(slot),
                                        )
                                    } else {
                                        None
                                    },
                                };
                                let dead =
                                    r.q.progress.as_ref().is_some_and(
                                        |ptx| ptx.send(ev).is_err(),
                                    );
                                if dead {
                                    r.q.progress = None;
                                }
                            }
                        }
                    }
                }
                if let Some(e) = download_err {
                    // the lazy decode download behind this request's
                    // progress stream failed: answer THIS request with
                    // a typed internal error (wire code `internal`,
                    // detail `token_download_failed`) instead of
                    // serving it a stale decode or failing the whole
                    // batch at the next step()
                    let r = running[slot].take().unwrap();
                    abort_download_failed(
                        cfg, sched, metrics, session, slot, r, executed, &e,
                    );
                    continue;
                }
                if halted || exhausted {
                    let r = running[slot].take().unwrap();
                    let halted_early = halted && !exhausted;
                    // lazy token fetch: on the resident session path
                    // this is the step's one [B,L] download
                    let tokens = session.slot_output(slot);
                    if let Some(e) = session.take_deferred_err() {
                        abort_download_failed(
                            cfg, sched, metrics, session, slot, r, executed,
                            &e,
                        );
                        continue;
                    }
                    let resp = GenResponse {
                        id: r.q.req.id,
                        tokens,
                        steps_executed: executed,
                        steps_budget: r.q.req.n_steps,
                        halted_early,
                        // a halt verdict names its primitive; a slot
                        // that ran out of unfrozen positions halted
                        // because every token froze
                        halt_reason: if halted_early {
                            decision
                                .reason()
                                .map(str::to_string)
                                .or_else(|| Some("all_frozen".to_string()))
                        } else {
                            None
                        },
                        latency_ms: r.started.elapsed().as_secs_f64() * 1e3,
                        queue_ms: (r.started - r.q.submitted).as_secs_f64()
                            * 1e3,
                        family: Some(cfg.family),
                        predicted_steps_remaining: if cfg.predict_wire {
                            r.last_prediction.map(|(rem, _)| rem)
                        } else {
                            None
                        },
                        predicted_total_steps: if cfg.predict_wire {
                            r.q.predicted_steps
                        } else {
                            None
                        },
                        final_stats: st,
                    };
                    // every natural completion trains the estimator:
                    // total halt-steps plus the per-bucket first-entry
                    // steps (entropy AND KL-slope) this generation
                    // recorded along the way
                    if let Some(est) = &cfg.predictor {
                        est.observe_completion_full(
                            cfg.family,
                            executed,
                            &visited_buckets(&r.bucket_entry),
                            &visited_slope(&r.slope_entry),
                        );
                    }
                    sched.finish(resp.id);
                    session.release_slot(slot);
                    done.push((resp, r));
                }
            }
        }

        // 4) ONE metrics guard per loop iteration (the steady-state hot
        //    path used to take 2-4): device-call counter, completion
        //    bookkeeping, occupancy/progress gauges
        {
            let mut wm = metrics.lock().unwrap();
            if stepped {
                wm.device_calls += 1;
            }
            for (resp, r) in &done {
                wm.record_completion(resp, r.q.req.priority, cfg.family);
                // token-halting lanes: how many positions froze, the
                // token-steps spent on pinned positions, and the
                // token-level budget saving those freezes represent
                if r.tokens_frozen > 0 {
                    wm.record_token_halting(
                        cfg.family,
                        r.tokens_frozen,
                        r.frozen_token_steps,
                        r.token_steps_saved,
                        (resp.steps_executed * session.seq_len) as u64,
                    );
                }
                // realized prediction error for the admission-time
                // estimate (MAE lane; natural completions only — a
                // client halt would grade the predictor on the
                // client's timing, not the halting signal's)
                if let Some(pred) = r.q.predicted_steps {
                    wm.record_prediction(
                        cfg.family,
                        pred as u64,
                        resp.steps_executed as u64,
                    );
                }
            }
            wm.slots_busy =
                running.iter().filter(|r| r.is_some()).count() as u64;
            wm.steps_in_flight = running
                .iter()
                .enumerate()
                .filter(|(_, r)| r.is_some())
                .map(|(slot, _)| session.slots[slot].step as u64)
                .sum();
        }
        // replies go out after the metrics commit (a client that reads
        // /metrics right after its done frame sees itself counted);
        // dropping `r` here ends its progress stream only after the
        // terminal response is on its way
        for (resp, r) in done {
            let _ = r.q.reply.send(Ok(resp));
        }
    }
    Ok(())
}

/// The estimator's training signal from one finished slot: every
/// entropy bucket the generation visited, with the step it first
/// entered it at.
fn visited_buckets(entry: &[Option<usize>; N_BUCKETS]) -> Vec<(usize, usize)> {
    entry
        .iter()
        .enumerate()
        .filter_map(|(b, s)| s.map(|s| (b, s)))
        .collect()
}

/// Same, for the KL-slope buckets the generation visited.
fn visited_slope(
    entry: &[Option<usize>; N_SLOPE_BUCKETS],
) -> Vec<(usize, usize)> {
    entry
        .iter()
        .enumerate()
        .filter_map(|(b, s)| s.map(|s| (b, s)))
        .collect()
}

/// Fail one request whose lazy decode download died: typed `internal`
/// error with detail `token_download_failed` to the submitter, steps
/// burned recorded, slot released.  `release_slot` may re-arm the
/// session's deferred error (it snapshots the decode again); that
/// re-arm is drained too — this slot's failure has been surfaced on
/// the affected request, it must not also poison the whole batch at
/// the next `step()`.
#[allow(clippy::too_many_arguments)]
fn abort_download_failed(
    cfg: &WorkerConfig,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
    session: &mut Session,
    slot: usize,
    r: Running,
    steps: usize,
    err: &str,
) {
    log_info!(
        "worker {}: token download failed for request {} ({err})",
        cfg.id,
        r.q.req.id
    );
    sched.finish(r.q.req.id);
    metrics
        .lock()
        .unwrap()
        .record_aborted_steps(cfg.family, steps as u64);
    session.release_slot(slot);
    let _ = session.take_deferred_err();
    let _ = r
        .q
        .reply
        .send(Err(ServeError::Internal("token_download_failed")));
}
