//! A worker shard: one OS thread owning one PJRT runtime and one batched
//! generation `Session`, pulling work from the shared [`Scheduler`].
//!
//! The xla handles are not `Send`, so each worker constructs its own
//! `Runtime` and compiles its own step artifact.  Workers may bind
//! different compiled batch sizes *and different model families*: a
//! `(Ddlm, 1)` worker turns individual ddlm requests around quickly
//! (latency shard) while a `(Ssd, 8)` worker soaks ssd throughput
//! traffic — the scheduler routes each request to a worker of its
//! family and its priority classes decide what every worker picks up
//! next (high before normal before low), so one fleet serves a
//! heterogeneous model mix without separate deployments.
//!
//! Per loop iteration a worker: admits queued requests into free slots
//! (continuous batching — slots freed by an early halt are refilled
//! mid-schedule), aborts slots whose request was cancelled or whose
//! deadline expired, *finalizes* slots whose request was gracefully
//! halted by the client (a normal completion carrying the current x0
//! decode and `halt_reason:"client"`), then advances all active slots
//! with one device call — emitting a throttled [`ProgressEvent`] to any
//! subscribed slot every `progress_every` executed steps.  Every
//! completed request goes through the shared
//! [`Metrics::record_completion`] bookkeeping.
//!
//! Families are registry ids ([`FamilyId`]): the worker resolves its
//! kernel through the open `sampler::registry`, and loads artifacts /
//! checkpoints under the kernel's `artifact_prefix()` — so a kernel
//! registered at runtime can serve on existing compiled artifacts.
//!
//! The binding is *elastic*: a [`RebindOrder`] (operator `rebind` verb
//! or the `--fleet auto` supervisor) makes the worker export every
//! in-flight slot back to the queue as a resumable [`ResumeState`],
//! rebuild its session under the new `(family, batch, checkpoint)` —
//! checkpoint bytes through the process-wide mmap artifact cache — and
//! rejoin, with zero dropped requests (a failed rebuild reverts to the
//! previous binding and answers the order typed).  Independently, a
//! mostly-frozen long-tail slot can *migrate* mid-generation to a
//! smaller live shard of the same family, reclaiming its slot here.

use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{GenResponse, ProgressEvent};
use super::scheduler::{
    Flagged, IdleWait, QueuedReq, RebindOrder, RebindReport, ResumeState,
    Scheduler, ServeError,
};
use crate::halting::{BoxedPolicy, Decision, NoHalt};
use crate::log_info;
use crate::util::fault;
use crate::util::sync::lock_or_recover;
use crate::models::store::ParamStore;
use crate::predictor::{
    bucket_for, slope_bucket_for, Estimator, N_BUCKETS, N_SLOPE_BUCKETS,
};
use crate::runtime::Runtime;
use crate::sampler::{FamilyId, Session, SlotRequest};

/// Migration trigger: at least this fraction of the slot's positions
/// must be freeze-pinned before it counts as "mostly frozen".
const MIGRATE_FROZEN_FRACTION: f32 = 0.5;

/// ...and at least this many steps must remain (predicted when the
/// estimator is live, else budget remaining) — migrating a slot about
/// to finish costs more than it reclaims.
const MIGRATE_MIN_REMAINING: usize = 8;

pub struct WorkerConfig {
    pub id: usize,
    pub artifact_dir: String,
    pub family: FamilyId,
    /// requested batch size; resolved to the nearest compiled artifact
    pub batch: usize,
    /// trained checkpoint (PBIN); falls back to init params when None
    pub checkpoint: Option<String>,
    /// the fleet's per-family checkpoint map: a rebind that changes
    /// family without naming a checkpoint resolves the new family's
    /// weights here (the old family's file can't serve it)
    pub checkpoints: Vec<(FamilyId, String)>,
    /// schedule envelope this shard serves (engine-level default or a
    /// per-family override)
    pub t_max: f32,
    pub t_min: f32,
    /// shared fleet estimator: this worker feeds it per-step latency
    /// and per-completion halt-step observations, and reads live
    /// remaining-steps estimates from it (None = no predictor)
    pub predictor: Option<Arc<Estimator>>,
    /// emit `predicted_steps_remaining` / `predicted_total_steps` on
    /// progress and done frames (the wire-visible predictor gate)
    pub predict_wire: bool,
    /// frozen-aware live migration: hand a mostly-frozen long-tail slot
    /// to a smaller live shard of the same family mid-generation
    pub migrate: bool,
}

/// The worker's live `(family, batch, checkpoint)` binding — what a
/// rebind order changes.  `WorkerConfig` keeps the startup values; this
/// is the current truth.
#[derive(Clone)]
struct Binding {
    family: FamilyId,
    batch: usize,
    checkpoint: Option<String>,
}

impl Binding {
    /// The binding a rebind order asks for.  `None` fields keep the
    /// current value — except that a family change without an explicit
    /// checkpoint re-resolves the checkpoint from the fleet's
    /// per-family map (the old family's weights can't serve the new
    /// one).  An empty checkpoint string drops to init params.
    fn apply(
        &self,
        order: &RebindOrder,
        fleet: &[(FamilyId, String)],
    ) -> Binding {
        let family = order.family.unwrap_or(self.family);
        let checkpoint = match &order.checkpoint {
            Some(p) if p.is_empty() => None,
            Some(p) => Some(p.clone()),
            None if family == self.family => self.checkpoint.clone(),
            None => fleet
                .iter()
                .find(|(f, _)| *f == family)
                .map(|(_, p)| p.clone()),
        };
        Binding {
            family,
            batch: order.batch.unwrap_or(self.batch),
            checkpoint,
        }
    }
}

/// Why the serve loop returned.
enum LoopExit {
    /// shutdown with a drained queue
    Shutdown,
    /// a rebind order arrived; the in-flight slots are already drained
    /// back to the queue — rebuild under the order's binding and rejoin
    Rebind {
        order: RebindOrder,
        /// requests exported back to the queue by the drain
        drained: usize,
        /// when the order was taken (start of the rebind_ms clock)
        taken: Instant,
    },
}

struct Running {
    q: QueuedReq,
    /// this slot's live policy (cloned from the request and reset on
    /// admission; the request keeps the pristine copy for its spec)
    policy: BoxedPolicy,
    started: Instant,
    /// step at which this generation *first* entered each entropy
    /// bucket — the estimator's conditioned-EMA training signal
    bucket_entry: [Option<usize>; N_BUCKETS],
    /// step at which this generation first entered each KL-slope
    /// bucket (the estimator's second conditioning feature)
    slope_entry: [Option<usize>; N_SLOPE_BUCKETS],
    /// previous step's KL stat — the per-slot slope signal is the
    /// one-step difference `kl - prev_kl`
    prev_kl: Option<f32>,
    /// positions freeze-pinned by this request's policy so far
    tokens_frozen: u64,
    /// token-steps spent stepping positions that were already frozen
    /// (the numerator of `frozen_step_fraction`)
    frozen_token_steps: u64,
    /// token-level steps saved: at each freeze, newly-frozen positions
    /// × the slot's remaining step budget
    token_steps_saved: u64,
    /// latest live re-estimate `(remaining, total)` for the wire
    last_prediction: Option<(usize, usize)>,
}

/// Spawn the worker thread.  It exits when the scheduler reports
/// shutdown with a drained queue; startup errors (missing artifacts,
/// bad checkpoint) surface through the join handle.
pub fn spawn(
    cfg: WorkerConfig,
    sched: Arc<Scheduler>,
    metrics: Arc<Mutex<Metrics>>,
) -> JoinHandle<Result<()>> {
    std::thread::spawn(move || {
        // worker_down must run even if run_worker panics: a stale
        // workers_live would keep the scheduler admitting requests
        // nobody will ever serve (clients hang instead of getting the
        // typed `unavailable` failover), so tie it to a Drop guard
        struct Down(Arc<Scheduler>, usize);
        impl Drop for Down {
            fn drop(&mut self) {
                self.0.worker_down(self.1);
            }
        }
        let _down = Down(sched.clone(), cfg.id);
        run_worker(&cfg, &sched, &metrics)
    })
}

/// Build one serving `Session` for a binding: checkpoint (or init
/// params) through the process-wide artifact cache, batch resolved to
/// the nearest compiled artifact.  Returns the session and the
/// *resolved* batch.
fn build_session(
    rt: &Runtime,
    artifact_dir: &str,
    bind: &Binding,
    seq_len: usize,
) -> Result<(Session, usize)> {
    // artifacts and checkpoints live under the kernel's artifact
    // prefix — for built-ins that is the family name, for registered
    // wrapper kernels the family whose compiled artifacts they reuse
    let prefix = bind.family.kernel().artifact_prefix();
    // checkpoint bytes come through the process-wide mmap-backed
    // artifact cache: N workers binding the same checkpoint share one
    // mapping, and a rebind back to a recently-used checkpoint is a
    // cache hit instead of a cold read
    let store = match &bind.checkpoint {
        Some(path) => ParamStore::load_cached(path, prefix)?,
        None => ParamStore::load_init_cached(artifact_dir, prefix)?,
    };
    // artifacts are compiled for fixed batch sizes; resolve the nearest
    // available one (>= requested, else the largest)
    let batch = rt.manifest.resolve_step_batch(prefix, seq_len, bind.batch)?;
    let session =
        Session::new(rt, bind.family, Rc::new(store), batch, seq_len)?;
    Ok((session, batch))
}

fn run_worker(
    cfg: &WorkerConfig,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
) -> Result<()> {
    let rt = Runtime::new(&cfg.artifact_dir)?;
    let m = rt.manifest.model.clone();
    let mut bind = Binding {
        family: cfg.family,
        batch: cfg.batch,
        checkpoint: cfg.checkpoint.clone(),
    };
    // while a rebind's new binding is being built: the binding to fall
    // back to if the build fails, and the order context (order, drained
    // count, rebind_ms clock) to answer once the build resolves
    let mut rollback: Option<Binding> = None;
    let mut order_ctx: Option<(RebindOrder, usize, Instant)> = None;
    loop {
        let (mut session, batch) =
            match build_session(&rt, &cfg.artifact_dir, &bind, m.seq_len) {
                Ok(ok) => ok,
                Err(e) => {
                    let Some(prev) = rollback.take() else {
                        // startup failure, or the rollback binding
                        // itself died: the Down guard fails this
                        // worker's routing over
                        return Err(e);
                    };
                    // the rebind target can't serve: answer the order
                    // typed and revert to the binding that worked —
                    // zero requests are lost either way (the drained
                    // ones are already back in the queue)
                    log_info!(
                        "worker {} rebind failed ({e}); reverting",
                        cfg.id
                    );
                    if let Some((order, _, _)) = order_ctx.take() {
                        if let Some(reply) = order.reply {
                            let _ = reply.send(Err(e.to_string()));
                        }
                    }
                    bind = prev;
                    continue;
                }
            };
        sched.register_worker_batch(cfg.id, batch);
        lock_or_recover(&metrics).slots_total = batch as u64;
        if let Some((order, drained, taken)) = order_ctx.take() {
            rollback = None;
            // re-point routing only now that the new session is live:
            // requests queued for the new family during the rebuild
            // were held, not rejected
            sched.complete_rebind(cfg.id, bind.family, batch);
            let report = RebindReport {
                worker: cfg.id,
                family: bind.family,
                batch,
                drained,
                rebind_ms: taken.elapsed().as_secs_f64() * 1e3,
            };
            {
                let mut wm = lock_or_recover(&metrics);
                wm.rebinds += 1;
                wm.rebind_requests_drained += drained as u64;
            }
            log_info!(
                "worker {} rebound: family={} batch={} drained={} \
                 rebind_ms={:.1}",
                cfg.id,
                bind.family.name(),
                batch,
                report.drained,
                report.rebind_ms
            );
            if let Some(reply) = order.reply {
                let _ = reply.send(Ok(report));
            }
        } else {
            log_info!(
                "worker {} up: family={} batch={} (requested {}) \
                 seq_len={} resident={}",
                cfg.id,
                bind.family.name(),
                batch,
                bind.batch,
                m.seq_len,
                session.resident()
            );
        }

        let mut running: Vec<Option<Running>> =
            (0..batch).map(|_| None).collect();
        let fam = bind.family;
        // extensible policy code runs inside the step loop; if it (or a
        // session invariant) panics, fail this worker's in-flight
        // requests over with a typed error before the unwind continues —
        // dropping their reply channels would surface to clients as an
        // untyped "reply channel closed" instead of the documented
        // `unavailable`
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || step_loop(cfg, fam, sched, metrics, &mut session, &mut running),
        ));
        let exit = match stepped {
            Ok(out) => out?,
            Err(panic) => {
                // with retry budget left and a live same-family peer,
                // each in-flight request is re-admitted (backoff, fresh
                // slot) instead of failing over — zero requests lost to
                // a worker panic
                for r in running.iter_mut().filter_map(Option::take) {
                    if let Some(q) = sched.fail_running(cfg.id, r.q) {
                        let _ = q.reply.send(Err(ServeError::Unavailable));
                    }
                }
                std::panic::resume_unwind(panic);
            }
        };
        match exit {
            LoopExit::Shutdown => break,
            LoopExit::Rebind {
                order,
                drained,
                taken,
            } => {
                rollback = Some(bind.clone());
                bind = bind.apply(&order, &cfg.checkpoints);
                order_ctx = Some((order, drained, taken));
                // drop the old session before building the new one so
                // its device buffers and checkpoint cache pin release
                // first — a rebind never holds both bindings resident
                drop(session);
            }
        }
    }
    let (completed, ratio) = {
        let wm = lock_or_recover(&metrics);
        (wm.requests_completed, wm.step_saving_ratio())
    };
    log_info!(
        "worker {} down: {} completed, saving ratio {:.3}",
        cfg.id,
        completed,
        ratio
    );
    Ok(())
}

/// The worker's serve loop: admit / reap / step / account, until the
/// scheduler reports shutdown with a drained queue or hands this
/// worker a rebind order (in-flight slots drain back to the queue as
/// resumable exports — zero requests dropped).
fn step_loop(
    cfg: &WorkerConfig,
    fam: FamilyId,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
    session: &mut Session,
    running: &mut [Option<Running>],
) -> Result<LoopExit> {
    let batch = session.batch;
    // reusable sweep scratch (occupied slots, their request ids, and
    // the scheduler's verdicts) — the hot loop allocates nothing per
    // iteration for the flag sweep
    let mut flag_slots: Vec<usize> = Vec::with_capacity(batch);
    let mut flag_ids: Vec<u64> = Vec::with_capacity(batch);
    let mut flags: Vec<Option<Flagged>> = Vec::with_capacity(batch);
    loop {
        // 0) a pending rebind order preempts everything: export every
        //    in-flight slot back to the queue mid-generation (another
        //    shard — or this one, rebuilt — imports and finishes them)
        //    and hand the order up to the rebuild loop
        if let Some(order) = sched.take_rebind(cfg.id) {
            let taken = Instant::now();
            let drained =
                drain_for_rebind(cfg, fam, sched, metrics, session, running);
            return Ok(LoopExit::Rebind {
                order,
                drained,
                taken,
            });
        }
        //    fully idle: sleep until work our family can serve arrives,
        //    a rebind order lands, or shutdown drains us
        if running.iter().all(Option::is_none) {
            match sched.wait_for_work(cfg.id) {
                IdleWait::Work => {}
                IdleWait::Rebind => continue,
                IdleWait::Exit => return Ok(LoopExit::Shutdown),
            }
        }

        // 1) admit queued requests into free slots (continuous
        //    batching; the scheduler only hands us our own family's
        //    requests).  Requests this session can't hold are rejected
        //    with a typed error, never a panic — admission normally
        //    filters them, but the scheduler may not know our seq_len
        //    (manifest read failed) and must not be trusted with it
        'admit: for slot in 0..batch {
            while running[slot].is_none() {
                let Some(mut q) = sched.next_for(cfg.id) else {
                    break 'admit;
                };
                // a drained/migrated request arrives with its exported
                // device state attached: import it instead of resetting
                let resume = q.resume.take();
                // park the request in its slot BEFORE running any
                // extensible policy code (clone/reset) or session
                // setup: if one of those panics, the catch_unwind
                // failover still sees this request and answers it with
                // a typed error instead of dropping its reply channel
                let r = running[slot].insert(Running {
                    policy: Box::new(NoHalt),
                    started: Instant::now(),
                    bucket_entry: [None; N_BUCKETS],
                    slope_entry: [None; N_SLOPE_BUCKETS],
                    prev_kl: None,
                    tokens_frozen: 0,
                    frozen_token_steps: 0,
                    token_steps_saved: 0,
                    last_prediction: None,
                    q,
                });
                if let Some(rs) = resume {
                    let rs = *rs;
                    if let Err(e) = session.import_slot(slot, &rs.export) {
                        // the export doesn't fit this session (shape /
                        // family drift): fail THIS request typed — the
                        // import validated-then-left the slot untouched
                        // lint:allow(panic-freedom): slot verified occupied by this loop
                        let r = running[slot].take().unwrap();
                        log_info!(
                            "worker {} cannot resume request {}: {e}",
                            cfg.id,
                            r.q.req.id
                        );
                        sched.finish(r.q.req.id);
                        lock_or_recover(&metrics).record_aborted_steps(
                            fam,
                            rs.export.step as u64,
                        );
                        let _ = r.q.reply.send(Err(ServeError::Internal(
                            "migration_import_failed",
                        )));
                        continue;
                    }
                    // the generation continues where it left off: live
                    // policy state (NOT reset), original admission
                    // clock (latency stays end-to-end), and the
                    // predictor's per-slot training trail
                    r.policy = rs.policy;
                    r.started = rs.started;
                    r.prev_kl = rs.prev_kl;
                    r.tokens_frozen = rs.tokens_frozen;
                    r.frozen_token_steps = rs.frozen_token_steps;
                    r.token_steps_saved = rs.token_steps_saved;
                    r.bucket_entry = rs.bucket_entry;
                    r.slope_entry = rs.slope_entry;
                    r.last_prediction = rs.last_prediction;
                    continue;
                }
                let mut policy = r.q.req.policy.clone();
                policy.reset();
                r.policy = policy;
                let reset = session.reset_slot(
                    slot,
                    &SlotRequest::new(
                        r.q.req.seed,
                        r.q.req.n_steps,
                        cfg.t_max,
                        cfg.t_min,
                    )
                    .noise(r.q.req.noise_scale)
                    .prefix(&r.q.req.prefix),
                );
                if let Err(e) = reset {
                    // typed backstop (overlong prefix / zero-step
                    // budget the scheduler should have filtered): the
                    // reset validated-then-left the slot untouched, so
                    // just answer and move on
                    // lint:allow(panic-freedom): slot verified occupied by this loop
                    let r = running[slot].take().unwrap();
                    log_info!(
                        "worker {} rejected request {}: {e}",
                        cfg.id,
                        r.q.req.id
                    );
                    sched.finish(r.q.req.id);
                    lock_or_recover(&metrics).rejected_invalid += 1;
                    let _ = r.q.reply.send(Err(ServeError::InvalidRequest));
                    continue;
                }
            }
        }

        // 2) sweep expired queued deadlines (so a saturated fleet still
        //    answers them within one step latency), then abort slots
        //    whose request was cancelled or whose deadline expired
        //    mid-schedule, and gracefully finalize slots whose request
        //    the client halted (cancel outranks halt)
        sched.reap_expired();
        let now = Instant::now();
        enum Sweep {
            Abort(ServeError),
            Finalize,
        }
        // ONE scheduler lock answers the cancel/halt flags for the
        // whole sweep (the per-slot check cost one lock per occupied
        // slot per iteration); precedence: cancel > deadline > halt
        flag_slots.clear();
        flag_ids.clear();
        for (slot, r) in running.iter().enumerate() {
            if let Some(r) = r {
                flag_slots.push(slot);
                flag_ids.push(r.q.req.id);
            }
        }
        sched.flagged_sweep_into(&flag_ids, &mut flags);
        for (&slot, &flagged) in flag_slots.iter().zip(&flags) {
            let Some(r) = running[slot].as_ref() else { continue };
            let action = if flagged == Some(Flagged::Cancel) {
                Some(Sweep::Abort(ServeError::Cancelled))
            } else if r.q.deadline.is_some_and(|d| now >= d) {
                Some(Sweep::Abort(ServeError::DeadlineExceeded))
            } else if flagged == Some(Flagged::Halt) {
                Some(Sweep::Finalize)
            } else {
                None
            };
            match action {
                None => {}
                Some(Sweep::Abort(err)) => {
                    // lint:allow(panic-freedom): slot verified occupied by this loop
                    let r = running[slot].take().unwrap();
                    sched.finish(r.q.req.id);
                    {
                        let mut wm = lock_or_recover(&metrics);
                        match err {
                            ServeError::Cancelled => wm.cancelled += 1,
                            _ => wm.deadline_exceeded += 1,
                        }
                        // steps burned before the abort still count —
                        // in the family lane too, so per-family steps
                        // reconcile with the fleet total
                        wm.record_aborted_steps(
                            fam,
                            session.slots[slot].step as u64,
                        );
                    }
                    session.release_slot(slot);
                    let _ = r.q.reply.send(Err(err));
                }
                Some(Sweep::Finalize) => {
                    // graceful client halt: a NORMAL completion with
                    // the slot's current x0 decode — the wire-visible
                    // form of the paper's early exit, so it shares the
                    // one completion bookkeeping path
                    // lint:allow(panic-freedom): slot verified occupied by this loop
                    let r = running[slot].take().unwrap();
                    let steps = session.slots[slot].step;
                    let tokens = session.slot_output(slot);
                    if let Some(e) = session.take_deferred_err() {
                        // the lazy decode download failed: this
                        // completion has no trustworthy tokens — fail
                        // THIS request with a typed internal error
                        // instead of poisoning the whole batch at the
                        // next step()
                        abort_download_failed(
                            cfg, fam, sched, metrics, session, slot, r,
                            steps, &e,
                        );
                        continue;
                    }
                    let resp = GenResponse {
                        id: r.q.req.id,
                        tokens,
                        steps_executed: steps,
                        steps_budget: r.q.req.n_steps,
                        halted_early: true,
                        halt_reason: Some("client".to_string()),
                        latency_ms: r.started.elapsed().as_secs_f64() * 1e3,
                        queue_ms: (r.started - r.q.submitted).as_secs_f64()
                            * 1e3,
                        family: Some(fam),
                        predicted_steps_remaining: if cfg.predict_wire {
                            r.last_prediction.map(|(rem, _)| rem)
                        } else {
                            None
                        },
                        predicted_total_steps: if cfg.predict_wire {
                            r.q.predicted_steps
                        } else {
                            None
                        },
                        final_stats: session.slots[slot].last_stats,
                    };
                    // predictor grading is optional work too: a
                    // browned-out fleet skips the estimator update and
                    // its queue re-sort
                    if let Some(est) = &cfg.predictor {
                        if !sched.health_is_brownout() {
                            est.observe_completion_full(
                                fam,
                                steps,
                                &visited_buckets(&r.bucket_entry),
                                &visited_slope(&r.slope_entry),
                            );
                            // fresh per-family evidence may reorder the
                            // same-class backlog (bounded SRPT re-sort)
                            sched.note_estimator_update();
                        }
                    }
                    sched.finish(resp.id);
                    {
                        let mut wm = lock_or_recover(&metrics);
                        wm.record_completion(&resp, r.q.req.priority, fam);
                        if r.tokens_frozen > 0 {
                            wm.record_token_halting(
                                fam,
                                r.tokens_frozen,
                                r.frozen_token_steps,
                                r.token_steps_saved,
                                (steps * session.seq_len) as u64,
                            );
                        }
                    }
                    session.release_slot(slot);
                    let _ = r.q.reply.send(Ok(resp));
                }
            }
        }

        // 3) one batched device step; responses are *collected* first —
        //    bookkeeping commits under the single metrics guard below,
        //    then the replies go out on the wire
        let stepped = running.iter().any(Option::is_some);
        let mut done: Vec<(GenResponse, Running)> = Vec::new();
        // frames evicted from slow subscribers' bounded progress
        // buffers this iteration (flushed under the metrics guard)
        let mut dropped_frames = 0u64;
        // slots handed to a smaller shard this iteration
        let mut migrated_count = 0u64;
        let mut migration_reclaimed = 0u64;
        if stepped {
            // deterministic chaos hooks: a fault schedule can kill
            // this worker or stretch its latency at an exact
            // device-step index (hit counters are per-point)
            if fault::check("worker_panic").is_some() {
                // lint:allow(panic-freedom): deterministic fault injection; the catch_unwind failover above answers every in-flight request
                panic!("injected worker_panic fault");
            }
            if let Some(fault::FaultAction::SleepMs(ms)) =
                fault::check("slow_step")
            {
                std::thread::sleep(Duration::from_millis(ms));
            }
            let step_started = Instant::now();
            let stats = match session.step() {
                Ok(stats) => stats,
                Err(e) => {
                    // device failure: re-admit in-flight requests on a
                    // surviving same-family peer (retry budget
                    // permitting), else fail them over typed — and
                    // release their scheduler state either way —
                    // before surfacing the error
                    for r in running.iter_mut().filter_map(Option::take) {
                        if let Some(q) = sched.fail_running(cfg.id, r.q) {
                            let _ =
                                q.reply.send(Err(ServeError::Unavailable));
                        }
                    }
                    return Err(e);
                }
            };
            // the batched step latency is the admission gate's
            // wall-time basis: one observation per device call
            if let Some(est) = &cfg.predictor {
                est.observe_step_latency(
                    fam,
                    step_started.elapsed().as_secs_f64() * 1e3,
                );
            }
            for slot in 0..batch {
                let Some(st) = stats[slot] else { continue };
                let Some(r) = running[slot].as_mut() else { continue };
                let executed = session.slots[slot].step;
                // token-steps the step that just ran spent on already-
                // pinned positions (numerator of frozen_step_fraction);
                // counted BEFORE this observe's freeze verdict applies
                r.frozen_token_steps += session.frozen_count(slot) as u64;
                // token-level observe when per-position lanes are live
                // (fused format-3 stats on a kernel that opts in); the
                // observe_tokens default makes sequence-level policies
                // behave identically on both call paths
                let decision = match session.slot_token_lanes(slot) {
                    Some(lanes) => {
                        r.policy.observe_tokens(executed - 1, &st, &lanes)
                    }
                    None => r.policy.observe(executed - 1, &st),
                };
                // apply a freeze verdict: the session clamps the masked
                // positions on-device like a dynamically-grown prefix;
                // a slot with every position pinned is done and
                // completes like a policy halt, reason "all_frozen"
                let mut all_frozen = false;
                if let Decision::Freeze { mask } = &decision {
                    match session.freeze_positions(slot, mask) {
                        Ok(newly) => {
                            if newly > 0 {
                                r.tokens_frozen += newly as u64;
                                r.token_steps_saved += newly as u64
                                    * r.q.req.n_steps.saturating_sub(executed)
                                        as u64;
                            }
                            all_frozen = session.fully_frozen(slot);
                        }
                        Err(e) => {
                            // freezing syncs the decode; a failed
                            // download fails THIS request, typed
                            // lint:allow(panic-freedom): slot verified occupied by this loop
                            let r = running[slot].take().unwrap();
                            abort_download_failed(
                                cfg,
                                fam,
                                sched,
                                metrics,
                                session,
                                slot,
                                r,
                                executed,
                                &e.to_string(),
                            );
                            continue;
                        }
                    }
                }
                let halted = decision.halted() || all_frozen;
                let exhausted = session.slot_exhausted(slot);
                // predictor plumbing: remember when this generation
                // first entered each entropy and KL-slope bucket (the
                // estimator's training signal), and — when prediction
                // is on the wire — refresh the live remaining-steps
                // estimate with the slot's slope and frozen-fraction
                // completeness features
                let kl_slope = r.prev_kl.map(|p| st.kl - p);
                r.prev_kl = Some(st.kl);
                if let Some(est) = &cfg.predictor {
                    let b = bucket_for(&st);
                    if r.bucket_entry[b].is_none() {
                        r.bucket_entry[b] = Some(executed);
                    }
                    if let Some(d) = kl_slope {
                        let sb = slope_bucket_for(d);
                        if r.slope_entry[sb].is_none() {
                            r.slope_entry[sb] = Some(executed);
                        }
                    }
                    if cfg.predict_wire {
                        let p = est.predict_remaining_with(
                            fam,
                            &st,
                            kl_slope,
                            session.frozen_fraction(slot),
                            executed,
                            r.q.req.n_steps,
                        );
                        r.last_prediction =
                            Some((p.steps, executed + p.steps));
                    }
                }
                // throttled progress fan-out: subscribed requests get
                // the paper's completeness estimates — and the current
                // decode (one lazy [B,L] token download shared by every
                // subscribed slot this step) — every `progress_every`
                // executed steps (terminal steps are reported by the
                // done frame instead).  A dead subscriber is dropped on
                // the first failed send so the hot loop never retries
                // into a closed channel.
                let mut download_err: Option<String> = None;
                if !(halted || exhausted) {
                    let every = r.q.req.progress_every.unwrap_or(0);
                    // brownout sheds optional work: progress frames
                    // (and their decode download) are suspended while
                    // browned out — subscribers just see a gap
                    if every > 0
                        && executed % every == 0
                        && r.q.progress.is_some()
                        && !sched.health_is_brownout()
                    {
                        let toks = session.slot_output(slot);
                        match session.take_deferred_err() {
                            Some(e) => download_err = Some(e),
                            None => {
                                let ev = ProgressEvent {
                                    id: r.q.req.id,
                                    step: executed,
                                    steps_budget: r.q.req.n_steps,
                                    stats: st,
                                    tokens: Some(toks),
                                    predicted_steps_remaining: r
                                        .last_prediction
                                        .map(|(rem, _)| rem),
                                    predicted_total_steps: r
                                        .last_prediction
                                        .map(|(_, tot)| tot),
                                    // per-position freeze state, only
                                    // for requests that asked for it —
                                    // default wire bytes are untouched
                                    frozen_mask: if r.q.req.frozen_mask {
                                        Some(
                                            session.slot_frozen_mask(slot),
                                        )
                                    } else {
                                        None
                                    },
                                };
                                if let Some(ptx) = r.q.progress.as_ref() {
                                    match ptx.send(ev) {
                                        // a send over the subscriber's
                                        // bounded buffer evicted stale
                                        // frames: account them
                                        Ok(evicted) => {
                                            dropped_frames += evicted;
                                        }
                                        Err(_) => r.q.progress = None,
                                    }
                                }
                            }
                        }
                    }
                }
                if let Some(e) = download_err {
                    // the lazy decode download behind this request's
                    // progress stream failed: answer THIS request with
                    // a typed internal error (wire code `internal`,
                    // detail `token_download_failed`) instead of
                    // serving it a stale decode or failing the whole
                    // batch at the next step()
                    // lint:allow(panic-freedom): slot verified occupied by this loop
                    let r = running[slot].take().unwrap();
                    abort_download_failed(
                        cfg, fam, sched, metrics, session, slot, r,
                        executed, &e,
                    );
                    continue;
                }
                if halted || exhausted {
                    // lint:allow(panic-freedom): slot verified occupied by this loop
                    let r = running[slot].take().unwrap();
                    let halted_early = halted && !exhausted;
                    // lazy token fetch: on the resident session path
                    // this is the step's one [B,L] download
                    let tokens = session.slot_output(slot);
                    if let Some(e) = session.take_deferred_err() {
                        abort_download_failed(
                            cfg, fam, sched, metrics, session, slot, r,
                            executed, &e,
                        );
                        continue;
                    }
                    let resp = GenResponse {
                        id: r.q.req.id,
                        tokens,
                        steps_executed: executed,
                        steps_budget: r.q.req.n_steps,
                        halted_early,
                        // a halt verdict names its primitive; a slot
                        // that ran out of unfrozen positions halted
                        // because every token froze
                        halt_reason: if halted_early {
                            decision
                                .reason()
                                .map(str::to_string)
                                .or_else(|| Some("all_frozen".to_string()))
                        } else {
                            None
                        },
                        latency_ms: r.started.elapsed().as_secs_f64() * 1e3,
                        queue_ms: (r.started - r.q.submitted).as_secs_f64()
                            * 1e3,
                        family: Some(fam),
                        predicted_steps_remaining: if cfg.predict_wire {
                            r.last_prediction.map(|(rem, _)| rem)
                        } else {
                            None
                        },
                        predicted_total_steps: if cfg.predict_wire {
                            r.q.predicted_steps
                        } else {
                            None
                        },
                        final_stats: st,
                    };
                    // every natural completion trains the estimator:
                    // total halt-steps plus the per-bucket first-entry
                    // steps (entropy AND KL-slope) this generation
                    // recorded along the way
                    // optional work: grading is suspended while the
                    // fleet is browned out (same gate as the halt path)
                    if let Some(est) = &cfg.predictor {
                        if !sched.health_is_brownout() {
                            est.observe_completion_full(
                                fam,
                                executed,
                                &visited_buckets(&r.bucket_entry),
                                &visited_slope(&r.slope_entry),
                            );
                            // fresh per-family evidence may reorder the
                            // same-class backlog (bounded SRPT re-sort)
                            sched.note_estimator_update();
                        }
                    }
                    sched.finish(resp.id);
                    session.release_slot(slot);
                    done.push((resp, r));
                }
            }
        }

        // 3b) frozen-aware live migration: a mostly-frozen long-tail
        //     slot finishes just as well on a smaller shard of the same
        //     family — export it back to the queue (front, priced at
        //     its remaining steps) for the smaller shard to import, and
        //     reclaim this slot for fresh batch work.  At most one slot
        //     per iteration; `next_for`'s anti-ping-pong guard keeps
        //     this worker from re-admitting its own export while
        //     another same-family worker lives.
        if cfg.migrate {
            for slot in 0..batch {
                let Some(r) = running[slot].as_ref() else { continue };
                if session.frozen_fraction(slot) < MIGRATE_FROZEN_FRACTION {
                    continue;
                }
                let step = session.slots[slot].step;
                let budget_rem = r.q.req.n_steps.saturating_sub(step);
                // remaining cost: live estimate when the predictor has
                // one, capped at the budget it can't exceed
                let remaining = r
                    .last_prediction
                    .map_or(budget_rem, |(rem, _)| rem.min(budget_rem));
                if remaining < MIGRATE_MIN_REMAINING {
                    continue;
                }
                if !sched.smaller_shard_live(cfg.id, fam) {
                    break;
                }
                let export = match session.export_slot(slot) {
                    Ok(e) => e,
                    Err(e) => {
                        // the export couldn't sync device state; the
                        // slot keeps running here — migration is an
                        // optimisation, never a failure path
                        log_info!(
                            "worker {} migration export failed for \
                             request {}: {e}",
                            cfg.id,
                            r.q.req.id
                        );
                        break;
                    }
                };
                // lint:allow(panic-freedom): slot verified occupied by this loop
                let r = running[slot].take().unwrap();
                let mut q = r.q;
                q.resume = Some(Box::new(ResumeState {
                    export,
                    policy: r.policy,
                    started: r.started,
                    prev_kl: r.prev_kl,
                    tokens_frozen: r.tokens_frozen,
                    frozen_token_steps: r.frozen_token_steps,
                    token_steps_saved: r.token_steps_saved,
                    bucket_entry: r.bucket_entry,
                    slope_entry: r.slope_entry,
                    last_prediction: r.last_prediction,
                    migrated_from: Some(cfg.id),
                }));
                session.release_slot(slot);
                let _ = session.take_deferred_err();
                let id = q.req.id;
                sched.requeue_drained(vec![q]);
                migrated_count += 1;
                migration_reclaimed += remaining as u64;
                log_info!(
                    "worker {} migrated request {} at step {} \
                     (frozen-heavy, ~{remaining} steps left)",
                    cfg.id,
                    id,
                    step
                );
                break;
            }
        }

        // 4) ONE metrics guard per loop iteration (the steady-state hot
        //    path used to take 2-4): device-call counter, completion
        //    bookkeeping, occupancy/progress gauges
        {
            let mut wm = lock_or_recover(&metrics);
            if stepped {
                wm.device_calls += 1;
            }
            if dropped_frames > 0 {
                wm.progress_dropped += dropped_frames;
            }
            if migrated_count > 0 {
                wm.slots_migrated += migrated_count;
                wm.migration_reclaimed_slot_steps += migration_reclaimed;
            }
            for (resp, r) in &done {
                wm.record_completion(resp, r.q.req.priority, fam);
                // token-halting lanes: how many positions froze, the
                // token-steps spent on pinned positions, and the
                // token-level budget saving those freezes represent
                if r.tokens_frozen > 0 {
                    wm.record_token_halting(
                        fam,
                        r.tokens_frozen,
                        r.frozen_token_steps,
                        r.token_steps_saved,
                        (resp.steps_executed * session.seq_len) as u64,
                    );
                }
                // realized prediction error for the admission-time
                // estimate (MAE lane; natural completions only — a
                // client halt would grade the predictor on the
                // client's timing, not the halting signal's)
                if let Some(pred) = r.q.predicted_steps {
                    wm.record_prediction(
                        fam,
                        pred as u64,
                        resp.steps_executed as u64,
                    );
                }
            }
            wm.slots_busy =
                running.iter().filter(|r| r.is_some()).count() as u64;
            wm.steps_in_flight = running
                .iter()
                .enumerate()
                .filter(|(_, r)| r.is_some())
                .map(|(slot, _)| session.slots[slot].step as u64)
                .sum();
        }
        // replies go out after the metrics commit (a client that reads
        // /metrics right after its done frame sees itself counted);
        // dropping `r` here ends its progress stream only after the
        // terminal response is on its way
        for (resp, r) in done {
            let _ = r.q.reply.send(Ok(resp));
        }
    }
}

/// Export every in-flight slot back to the scheduler queue as a
/// resumable request (front of its class, priced at remaining steps).
/// A slot whose device state can't be exported is answered with a
/// typed error — a rebind drain never silently drops a request.
/// Returns how many requests were requeued.
fn drain_for_rebind(
    cfg: &WorkerConfig,
    fam: FamilyId,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
    session: &mut Session,
    running: &mut [Option<Running>],
) -> usize {
    let mut items: Vec<QueuedReq> = Vec::new();
    for slot in 0..session.batch {
        let Some(r) = running[slot].take() else { continue };
        match session.export_slot(slot) {
            Ok(export) => {
                let mut q = r.q;
                q.resume = Some(Box::new(ResumeState {
                    export,
                    policy: r.policy,
                    started: r.started,
                    prev_kl: r.prev_kl,
                    tokens_frozen: r.tokens_frozen,
                    frozen_token_steps: r.frozen_token_steps,
                    token_steps_saved: r.token_steps_saved,
                    bucket_entry: r.bucket_entry,
                    slope_entry: r.slope_entry,
                    last_prediction: r.last_prediction,
                    // a rebind drain is not a migration: the request
                    // may come straight back to this worker once it
                    // rejoins
                    migrated_from: None,
                }));
                session.release_slot(slot);
                items.push(q);
            }
            Err(e) => {
                log_info!(
                    "worker {} rebind drain export failed for request \
                     {}: {e}",
                    cfg.id,
                    r.q.req.id
                );
                sched.finish(r.q.req.id);
                lock_or_recover(&metrics).record_aborted_steps(
                    fam,
                    session.slots[slot].step as u64,
                );
                session.release_slot(slot);
                let _ = r.q.reply.send(Err(ServeError::Internal(
                    "rebind_export_failed",
                )));
            }
        }
    }
    // the drained session is torn down next; a deferred decode-download
    // error from the release sweep has no batch left to poison
    let _ = session.take_deferred_err();
    let n = items.len();
    sched.requeue_drained(items);
    n
}

/// The estimator's training signal from one finished slot: every
/// entropy bucket the generation visited, with the step it first
/// entered it at.
fn visited_buckets(entry: &[Option<usize>; N_BUCKETS]) -> Vec<(usize, usize)> {
    entry
        .iter()
        .enumerate()
        .filter_map(|(b, s)| s.map(|s| (b, s)))
        .collect()
}

/// Same, for the KL-slope buckets the generation visited.
fn visited_slope(
    entry: &[Option<usize>; N_SLOPE_BUCKETS],
) -> Vec<(usize, usize)> {
    entry
        .iter()
        .enumerate()
        .filter_map(|(b, s)| s.map(|s| (b, s)))
        .collect()
}

/// Fail one request whose lazy decode download died: typed `internal`
/// error with detail `token_download_failed` to the submitter, steps
/// burned recorded, slot released.  `release_slot` may re-arm the
/// session's deferred error (it snapshots the decode again); that
/// re-arm is drained too — this slot's failure has been surfaced on
/// the affected request, it must not also poison the whole batch at
/// the next `step()`.
#[allow(clippy::too_many_arguments)]
fn abort_download_failed(
    cfg: &WorkerConfig,
    fam: FamilyId,
    sched: &Scheduler,
    metrics: &Mutex<Metrics>,
    session: &mut Session,
    slot: usize,
    r: Running,
    steps: usize,
    err: &str,
) {
    log_info!(
        "worker {}: token download failed for request {} ({err})",
        cfg.id,
        r.q.req.id
    );
    sched.finish(r.q.req.id);
    lock_or_recover(&metrics).record_aborted_steps(fam, steps as u64);
    session.release_slot(slot);
    let _ = session.take_deferred_err();
    let _ = r
        .q
        .reply
        .send(Err(ServeError::Internal("token_download_failed")));
}
