//! Serving metrics: latency histogram, step accounting, steps-saved,
//! per-reason halt counters — the numbers behind the paper's headline
//! "10-40% faster generation".

use std::collections::BTreeMap;
use std::time::Instant;

/// Fixed-bucket latency histogram (milliseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1ms .. ~2min, roughly x2 per bucket
        let bounds: Vec<f64> = (0..18).map(|i| 1.0 * 2f64.powi(i)).collect();
        Histogram {
            counts: vec![0; bounds.len() + 1],
            bounds,
            sum: 0.0,
            n: 0,
            max: 0.0,
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket upper bounds (conservative).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }
}

/// Aggregate serving metrics for one engine.
#[derive(Debug)]
pub struct Metrics {
    pub started_at: Instant,
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub halted_early: u64,
    /// denoiser steps actually executed (per-request accounting)
    pub steps_executed: u64,
    /// steps the requests budgeted but never ran (saved by halting)
    pub steps_saved: u64,
    /// device calls (batched steps)
    pub device_calls: u64,
    pub latency_ms: Histogram,
    /// early halts per policy reason (`entropy`, `patience`, ...);
    /// surfaced in the JSON snapshot as `halted_by_<reason>`
    pub halted_by: BTreeMap<&'static str, u64>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started_at: Instant::now(),
            requests_submitted: 0,
            requests_completed: 0,
            halted_early: 0,
            steps_executed: 0,
            steps_saved: 0,
            device_calls: 0,
            latency_ms: Histogram::default(),
            halted_by: BTreeMap::new(),
        }
    }
}

impl Metrics {
    /// Account one early halt attributed to a policy reason.
    pub fn record_halt(&mut self, reason: &'static str) {
        self.halted_early += 1;
        *self.halted_by.entry(reason).or_insert(0) += 1;
    }

    pub fn throughput_rps(&self) -> f64 {
        let el = self.started_at.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.requests_completed as f64 / el
        }
    }

    /// Fraction of budgeted steps avoided by early halting.
    pub fn step_saving_ratio(&self) -> f64 {
        let total = self.steps_executed + self.steps_saved;
        if total == 0 {
            0.0
        } else {
            self.steps_saved as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let base = Json::obj(vec![
            ("requests_submitted", Json::num(self.requests_submitted as f64)),
            ("requests_completed", Json::num(self.requests_completed as f64)),
            ("halted_early", Json::num(self.halted_early as f64)),
            ("steps_executed", Json::num(self.steps_executed as f64)),
            ("steps_saved", Json::num(self.steps_saved as f64)),
            ("step_saving_ratio", Json::num(self.step_saving_ratio())),
            ("device_calls", Json::num(self.device_calls as f64)),
            ("latency_mean_ms", Json::num(self.latency_ms.mean())),
            ("latency_p50_ms", Json::num(self.latency_ms.quantile(0.5))),
            ("latency_p95_ms", Json::num(self.latency_ms.quantile(0.95))),
            ("throughput_rps", Json::num(self.throughput_rps())),
        ]);
        let Json::Obj(mut m) = base else { unreachable!() };
        for (reason, n) in &self.halted_by {
            m.insert(format!("halted_by_{reason}"), Json::num(*n as f64));
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let mut h = Histogram::default();
        for v in [1.0, 2.0, 4.0, 8.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 3.75).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 2.0 && h.quantile(0.5) <= 4.0);
        assert!(h.quantile(1.0) >= 8.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.9), 0.0);
    }

    #[test]
    fn saving_ratio() {
        let mut m = Metrics::default();
        m.steps_executed = 600;
        m.steps_saved = 400;
        assert!((m.step_saving_ratio() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn metrics_json_has_headline_fields() {
        let m = Metrics::default();
        let j = m.to_json();
        assert!(j.get("step_saving_ratio").is_some());
        assert!(j.get("latency_p95_ms").is_some());
    }

    #[test]
    fn per_reason_halt_counters_flattened_into_json() {
        let mut m = Metrics::default();
        m.record_halt("entropy");
        m.record_halt("entropy");
        m.record_halt("kl");
        assert_eq!(m.halted_early, 3);
        let j = m.to_json();
        assert_eq!(
            j.get("halted_by_entropy").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(j.get("halted_by_kl").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("halted_by_patience").is_none());
    }
}
