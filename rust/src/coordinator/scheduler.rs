//! Admission control for the serving fleet: a bounded, priority-classed,
//! family-routed queue between the front-ends and the worker shards.
//!
//! Responsibilities:
//!
//! * **admission** — requests whose policy resolves in preflight (e.g.
//!   `fixed:0`) or whose step budget is zero are answered here, without
//!   touching a worker; everything else enters a bounded queue, and a
//!   full queue rejects with the typed [`ServeError::Overloaded`]
//!   instead of growing without bound (backpressure).  Optional
//!   per-priority-class bounds reject a full class the same way without
//!   starving the other classes;
//! * **validation** — requests the fleet can never serve (prefix longer
//!   than the compiled seq_len, or a family no live worker runs) or
//!   whose id is already in flight are rejected with typed errors
//!   ([`ServeError::InvalidRequest`], [`ServeError::DuplicateId`]) at
//!   the boundary, never deeper in;
//! * **family routing** — the fleet may mix worker shards of different
//!   model families; a request (wire field `family`, default = the
//!   fleet's default family) is only ever handed to a worker whose
//!   kernel matches;
//! * **priority** — three classes (high / normal / low), FIFO within a
//!   class; workers always drain higher classes first;
//! * **deadlines** — a request carrying `deadline_ms` is dropped with
//!   [`ServeError::DeadlineExceeded`] if it expires while queued (the
//!   owning worker enforces the same deadline once it is running); with
//!   the fleet predictor's admission gate on, a deadline that is
//!   infeasible *up front* ((predicted steps + predicted steps already
//!   queued ahead for the family) × observed per-step latency exceeds
//!   it) is rejected at submit with the typed
//!   [`ServeError::InfeasibleDeadline`] before any device work — the
//!   expected queue wait is priced in, so a fast device behind a deep
//!   backlog rejects just like a slow device;
//! * **predictive packing** — with the predictor's SRPT gate on,
//!   `next_for` picks the same-priority candidate with the fewest
//!   predicted remaining steps instead of strict FIFO (ties and
//!   cold-start estimates keep submission order);
//! * **per-family bounds** — optional per-family queue caps keep one
//!   family's burst from consuming the whole shared queue; a full
//!   family rejects with the typed [`ServeError::Overloaded`];
//! * **cancellation** — [`Scheduler::cancel`] removes a queued request
//!   immediately, or flags a running one so its worker aborts it between
//!   device steps;
//! * **graceful halting** — [`Scheduler::halt`] is the client-visible
//!   form of the paper's early exit: a queued request is finalized here
//!   with a zero-step decode, a running one is flagged so its worker
//!   *completes* it between device steps — a normal response carrying
//!   the current x0 decode and `halt_reason:"client"`, not an error;
//! * **progress fan-out** — a submit may attach a progress subscriber
//!   ([`ProgressTx`]); the owning worker streams throttled per-step
//!   [`ProgressEvent`]s (the paper's completeness estimates) to it.
//!
//! The scheduler is shared (`Arc`) between every front-end thread and
//! every worker; all state sits behind one mutex, with a condvar waking
//! idle workers on new work or shutdown.  Lock discipline: the state
//! mutex and the metrics mutex are never held at the same time; the
//! journal's internal lock nests one-directionally *inside* the state
//! mutex (submit appends the admit record before releasing state, so
//! no resolve can precede its admit) and never the other way around.
//!
//! Robustness layers (armed per-scheduler, all off by default):
//!
//! * **write-ahead journal** ([`Self::with_journal`]) — queued
//!   admissions and every terminal resolution are appended to
//!   [`super::journal::Journal`]; restart replays the incomplete set;
//! * **worker-death retries** ([`Self::with_retry_budget`]) — a
//!   request lost to a worker panic or device failure is re-admitted
//!   (bounded attempts, exponential backoff) instead of failing over
//!   to `unavailable`, when another live worker serves its family;
//! * **brownout machine** ([`Self::with_brownout`]) — queue pressure
//!   and dead workers drive `healthy` → `degraded` → `browned_out`
//!   ([`FleetHealth`]); entering brownout sheds the low-priority
//!   queue, workers suspend optional work, and error frames carry a
//!   `retry_after_ms` hint; recovery is hysteretic.
//!
//! Families are [`FamilyId`]s from the open `sampler::registry`, so a
//! kernel registered at runtime routes exactly like a built-in; the
//! per-family tables grow on demand (an id registered after this
//! scheduler was built simply counts zero live workers until a fleet
//! serves it).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::journal::Journal;
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse, Priority, ProgressEvent};
use crate::halting::BoxedPolicy;
use crate::util::sync::{
    lock_or_recover, wait_or_recover, wait_timeout_or_recover,
};
use crate::predictor::{
    check_feasibility, Estimator, Feasibility, PackingMode, N_BUCKETS,
    N_SLOPE_BUCKETS,
};
use crate::sampler::{Family, FamilyId, SlotExport};

/// Typed serving-path failure, delivered instead of a [`GenResponse`]
/// (on the wire: `{"error": "<as_str()>"}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// the bounded admission queue (or the request's priority class) is
    /// full, or the engine is shutting down — back off and retry
    Overloaded,
    /// request cancelled via `cancel(id)` while queued or running
    Cancelled,
    /// `deadline_ms` elapsed before the request completed
    DeadlineExceeded,
    /// the fleet predictor judged `deadline_ms` unmeetable at submit
    /// (predicted steps × observed per-step latency exceeds it) —
    /// rejected before any device work; raise the deadline or drop it
    InfeasibleDeadline,
    /// no live worker is left to serve the queue (startup failure)
    Unavailable,
    /// the request can never be served by this fleet (e.g. its prefix
    /// is longer than the compiled sequence length, or it names a
    /// family no live worker runs) — fix and resubmit
    InvalidRequest,
    /// another in-flight request already uses this id; ids key the
    /// cancellation routing, so they must be unique while live
    DuplicateId,
    /// server-side failure while serving an otherwise-valid request;
    /// the payload is a machine-readable detail (e.g.
    /// `"token_download_failed"`) carried as the v1 error `message`
    Internal(&'static str),
}

impl ServeError {
    pub fn as_str(self) -> &'static str {
        match self {
            ServeError::Overloaded => "overloaded",
            ServeError::Cancelled => "cancelled",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::InfeasibleDeadline => "infeasible_deadline",
            ServeError::Unavailable => "unavailable",
            ServeError::InvalidRequest => "invalid_request",
            ServeError::DuplicateId => "duplicate_id",
            ServeError::Internal(_) => "internal",
        }
    }

    /// Machine-readable detail beyond the taxonomy code, when one
    /// exists (today: the `internal` payload).
    pub fn detail(self) -> Option<&'static str> {
        match self {
            ServeError::Internal(d) => Some(d),
            _ => None,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request resolves to: exactly one `Ok(response)` or
/// `Err(serve_error)` arrives per submission.
pub type GenOutcome = Result<GenResponse, ServeError>;

/// Reply channel for one request.
pub type ReplyTx = mpsc::Sender<GenOutcome>;

/// Fleet-health verdict of the brownout state machine (off by
/// default; armed with [`Scheduler::with_brownout`]).  Escalation is
/// immediate; recovery is hysteretic — the raw signal must stay clear
/// for the configured recovery window before the fleet steps back
/// down, so health can't flap at a threshold boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetHealth {
    /// normal operation
    Healthy,
    /// sustained pressure (deep queue or a dead worker): clients
    /// should back off briefly
    Degraded,
    /// near-saturation: low-priority queued work is shed, optional
    /// work (progress fan-out, predictor grading) is suspended
    BrownedOut,
}

impl FleetHealth {
    pub fn as_str(self) -> &'static str {
        match self {
            FleetHealth::Healthy => "healthy",
            FleetHealth::Degraded => "degraded",
            FleetHealth::BrownedOut => "browned_out",
        }
    }

    /// Suggested client backoff, attached to `overloaded`/
    /// `unavailable` v1 error frames as `retry_after_ms` (absent when
    /// healthy, so pre-brownout wire bytes stay pinned).
    pub fn retry_after_ms(self) -> Option<u64> {
        match self {
            FleetHealth::Healthy => None,
            FleetHealth::Degraded => Some(RETRY_AFTER_DEGRADED_MS),
            FleetHealth::BrownedOut => Some(RETRY_AFTER_BROWNOUT_MS),
        }
    }

    fn from_u8(v: u8) -> FleetHealth {
        match v {
            2 => FleetHealth::BrownedOut,
            1 => FleetHealth::Degraded,
            _ => FleetHealth::Healthy,
        }
    }
}

/// `retry_after_ms` hint on error frames while degraded.
pub const RETRY_AFTER_DEGRADED_MS: u64 = 500;

/// `retry_after_ms` hint on error frames while browned out.
pub const RETRY_AFTER_BROWNOUT_MS: u64 = 2000;

/// A request's reply handle: the raw channel plus the write-ahead
/// journal hookup.  Every terminal resolution in the stack goes
/// through exactly one `send`, so journaling here makes the resolve
/// record exhaustive by construction — no resolution path can forget
/// it.  The journal reference is `None` for immediate (preflight)
/// answers and journal-less schedulers.
pub struct Reply {
    tx: ReplyTx,
    journal: Option<Arc<Journal>>,
    id: u64,
}

impl Reply {
    /// Journal the outcome (`"ok"` or the taxonomy code), then forward
    /// it to the submitter.
    pub fn send(
        &self,
        outcome: GenOutcome,
    ) -> Result<(), mpsc::SendError<GenOutcome>> {
        if let Some(j) = &self.journal {
            let code = match &outcome {
                Ok(_) => "ok",
                Err(e) => e.as_str(),
            };
            j.resolve(self.id, code);
        }
        self.tx.send(outcome)
    }
}

/// Progress-subscriber channel for one request: the owning worker sends
/// a throttled [`ProgressEvent`] every `progress_every` executed steps.
/// Bounded per subscriber (drop-oldest beyond the buffer): one stalled
/// reader can neither block the worker's hot loop nor buffer frames
/// without limit — see [`super::progress`].
pub type ProgressTx = super::progress::Sender<ProgressEvent>;

/// Receiving half of a progress subscription.
pub type ProgressRx = super::progress::Receiver<ProgressEvent>;

/// Mid-generation state a drained or migrating slot carries back
/// through the queue: the device-state export plus the owning worker's
/// per-slot bookkeeping, so the destination worker resumes the request
/// bit-exactly where the source left it (same RNG, same frozen pins,
/// same policy state, continuous latency clock).
pub struct ResumeState {
    /// the slot's full generation state ([`crate::sampler::Session`]
    /// export/import pair)
    pub export: SlotExport,
    /// the live halting policy, mid-observation (NOT reset on
    /// re-admission — resetting would forget its accumulated signal)
    pub policy: BoxedPolicy,
    /// original admission instant — `latency_ms` stays continuous
    /// across the migration
    pub started: Instant,
    /// previous step's KL (the per-slot slope signal)
    pub prev_kl: Option<f32>,
    pub tokens_frozen: u64,
    pub frozen_token_steps: u64,
    pub token_steps_saved: u64,
    /// estimator training signal: first-entry step per entropy bucket
    pub bucket_entry: [Option<usize>; N_BUCKETS],
    /// first-entry step per KL-slope bucket
    pub slope_entry: [Option<usize>; N_SLOPE_BUCKETS],
    /// latest live `(remaining, total)` re-estimate for the wire
    pub last_prediction: Option<(usize, usize)>,
    /// the worker a *migration* left (None for rebind drains): while
    /// another live worker serves the family, `next_for` skips the
    /// source so a migrated slot can't ping-pong home
    pub migrated_from: Option<usize>,
}

/// An operator (or `--fleet auto`) order for one worker: drain, rebuild
/// the session against the new binding, rejoin.  `None` fields keep the
/// worker's current value — a checkpoint-only order is a hot-swap, a
/// family/batch order is a reshape.
pub struct RebindOrder {
    pub family: Option<FamilyId>,
    pub batch: Option<usize>,
    /// new checkpoint path; `Some(None)` would be ambiguous on the
    /// wire, so the empty string means "drop back to init params"
    pub checkpoint: Option<String>,
    /// where the rebind report (or a typed failure) is answered;
    /// `None` for fire-and-forget supervisor orders
    pub reply: Option<mpsc::Sender<Result<RebindReport, String>>>,
}

/// What a completed rebind reports back to its requester.
#[derive(Clone, Debug)]
pub struct RebindReport {
    pub worker: usize,
    /// binding after the rebind
    pub family: FamilyId,
    pub batch: usize,
    /// in-flight requests drained back to the queue (all of them were
    /// re-admitted elsewhere or by this worker after the rebind — the
    /// zero-dropped-requests invariant)
    pub drained: usize,
    pub rebind_ms: f64,
}

/// A queued request plus its reply channel, progress subscriber,
/// resolved family, and timing/deadline state.
pub struct QueuedReq {
    pub req: GenRequest,
    pub reply: Reply,
    /// per-step progress subscriber (None = one-shot request); dropped
    /// by the worker on the first failed send
    pub progress: Option<ProgressTx>,
    /// model family resolved at admission (request field, else the
    /// fleet default) — the routing key
    pub family: FamilyId,
    pub submitted: Instant,
    /// absolute expiry computed from `req.deadline_ms` at submission
    pub deadline: Option<Instant>,
    /// total steps the fleet predictor expected at admission (None when
    /// the scheduler runs without a predictor) — drives SRPT packing
    /// and, via the worker, the wire's `predicted_total_steps`
    pub predicted_steps: Option<usize>,
    /// mid-generation state from a drain or migration; the admitting
    /// worker imports it instead of resetting a fresh slot
    pub resume: Option<Box<ResumeState>>,
    /// worker-death retries consumed so far (bounded by the
    /// scheduler's retry budget)
    pub attempts: u32,
    /// retry backoff: `next_for` skips this entry until the instant
    /// passes (exponential per attempt)
    pub not_before: Option<Instant>,
}

impl QueuedReq {
    fn new(
        req: GenRequest,
        reply: Reply,
        progress: Option<ProgressTx>,
        family: FamilyId,
        predicted_steps: Option<usize>,
    ) -> QueuedReq {
        let submitted = Instant::now();
        let deadline = req
            .deadline_ms
            .map(|ms| submitted + Duration::from_secs_f64(ms.max(0.0) / 1e3));
        QueuedReq {
            req,
            reply,
            progress,
            family,
            submitted,
            deadline,
            predicted_steps,
            resume: None,
            attempts: 0,
            not_before: None,
        }
    }
}

/// What [`Scheduler::cancel`] found.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// removed from the queue; the submitter got `Err(Cancelled)`
    Queued,
    /// flagged; the owning worker aborts it between device steps
    Running,
    NotFound,
}

impl CancelOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            CancelOutcome::Queued => "queued",
            CancelOutcome::Running => "running",
            CancelOutcome::NotFound => "not_found",
        }
    }

    /// True when the cancel reached a live request.
    pub fn found(self) -> bool {
        !matches!(self, CancelOutcome::NotFound)
    }
}

/// Outcome of an idle worker's wait.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdleWait {
    /// work this worker's family can serve is queued — go admit it
    Work,
    /// shutdown with a drained queue — exit the worker loop
    Exit,
    /// a rebind order is pending for this worker — take and run it
    Rebind,
}

/// What [`Scheduler::flagged`] found for a running request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flagged {
    /// abort: answer `Err(Cancelled)`
    Cancel,
    /// graceful client halt: finalize with the current decode
    Halt,
}

/// Grow-on-demand per-family counter table (indexed by
/// `FamilyId::index()`; ids registered after construction land beyond
/// the initial length and the table stretches to hold them).
fn tab_inc(tab: &mut Vec<usize>, idx: usize) {
    if idx >= tab.len() {
        tab.resize(idx + 1, 0);
    }
    tab[idx] += 1;
}

fn tab_dec(tab: &mut [usize], idx: usize) {
    if let Some(v) = tab.get_mut(idx) {
        *v = v.saturating_sub(1);
    }
}

fn tab_get(tab: &[usize], idx: usize) -> usize {
    tab.get(idx).copied().unwrap_or(0)
}

/// Variable-amount variants for the predicted-steps table.
fn tab_add(tab: &mut Vec<usize>, idx: usize, n: usize) {
    if idx >= tab.len() {
        tab.resize(idx + 1, 0);
    }
    tab[idx] += n;
}

fn tab_sub(tab: &mut [usize], idx: usize, n: usize) {
    if let Some(v) = tab.get_mut(idx) {
        *v = v.saturating_sub(n);
    }
}

/// A queued request's contribution to its family's predicted-steps
/// backlog: the admission-time prediction, or the full budget when it
/// was admitted without one (cold start / predictor off — pessimistic,
/// same convention as SRPT packing).  A drained/migrating request costs
/// exactly its remaining schedule — mostly-done slots therefore sort
/// near the front under SRPT and price almost nothing at admission.
fn queued_cost(q: &QueuedReq) -> usize {
    if let Some(r) = &q.resume {
        return r.export.steps_remaining();
    }
    q.predicted_steps.unwrap_or(q.req.n_steps)
}

struct State {
    queues: [VecDeque<QueuedReq>; Priority::COUNT],
    queued: usize,
    /// queued requests per family — the idle-wait predicate (a worker
    /// must not busy-wake on work only another family can serve)
    queued_by_family: Vec<usize>,
    /// predicted steps queued per family ([`queued_cost`] summed over
    /// the family's queued requests) — the admission gate's expected
    /// queue wait; kept in lockstep with `queued_by_family` at every
    /// mutation site
    queued_steps_by_family: Vec<usize>,
    /// request id -> owning worker, for every admitted-but-unfinished
    /// request (cancellation routing)
    running: HashMap<u64, usize>,
    /// running ids flagged for cancellation
    cancel_flags: HashSet<u64>,
    /// running ids flagged for graceful client halt (the worker
    /// *completes* these with the current decode, not an error)
    halt_flags: HashSet<u64>,
    /// every queued-or-running id; admission rejects duplicates so the
    /// cancellation routing above can never be corrupted by two live
    /// requests sharing an id
    live_ids: HashSet<u64>,
    /// workers that have not exited (starts at the spawned count)
    workers_live: usize,
    /// live workers per family — admission rejects families nobody
    /// serves with a typed `invalid_request`
    family_live: Vec<usize>,
    /// family per worker id (the routing table).  Lives in the mutable
    /// state, not the scheduler: a rebind re-points it live.
    worker_family: Vec<FamilyId>,
    /// resolved compiled batch per worker (0 until the worker reports
    /// in) — the migration policy's shard-size signal
    worker_batch: Vec<usize>,
    /// per-worker liveness (worker_down flips it; `workers_live` is
    /// the count, this is the roster)
    worker_alive: Vec<bool>,
    /// pending drain→rebind→rejoin order per worker, taken exactly
    /// once by the owning worker
    rebind_orders: Vec<Option<RebindOrder>>,
    shutdown: bool,
    /// brownout machine state (`FleetHealth` as u8; 0 until armed)
    health: u8,
    /// when the raw health signal first read *below* the current
    /// level — recovery steps down only after it stays clear for the
    /// configured window (hysteresis)
    health_clear_since: Option<Instant>,
}

/// Under the state lock: when `fam` has no live worker left, drain its
/// queued requests (they fail over to `Unavailable`) and zero its
/// tables — submitters must never block on work nobody will drain.
fn drain_family_if_dead(st: &mut State, fam: FamilyId) -> Vec<QueuedReq> {
    let fi = fam.index();
    if tab_get(&st.family_live, fi) != 0 {
        return Vec::new();
    }
    let mut drained = Vec::new();
    for q in st.queues.iter_mut() {
        let mut k = 0;
        while k < q.len() {
            if q[k].family == fam {
                // remove(k) is Some: k < q.len() by the loop guard
                drained.extend(q.remove(k));
            } else {
                k += 1;
            }
        }
    }
    st.queued -= drained.len();
    if let Some(v) = st.queued_by_family.get_mut(fi) {
        *v = 0;
    }
    if let Some(v) = st.queued_steps_by_family.get_mut(fi) {
        *v = 0;
    }
    for q in &drained {
        st.live_ids.remove(&q.req.id);
    }
    drained
}

/// The scheduler's handle on the fleet predictor: the shared estimator
/// plus which of its admission-side features are switched on.
struct SchedPredictor {
    est: Arc<Estimator>,
    /// reject infeasible deadlines with `InfeasibleDeadline`
    admission: bool,
    /// queue-ordering discipline for `next_for`
    packing: PackingMode,
}

pub struct Scheduler {
    state: Mutex<State>,
    work_ready: Condvar,
    queue_cap: usize,
    /// optional per-priority-class caps (defaults to the shared
    /// `queue_cap` only); a full class rejects with `overloaded`
    /// without starving the other classes
    class_caps: [usize; Priority::COUNT],
    /// optional per-family queue caps (sparse; families not listed are
    /// bounded only by `queue_cap`) — one family's burst can't consume
    /// the whole shared queue
    family_caps: Vec<(FamilyId, usize)>,
    /// fleet predictor hookup (None = no prediction at admission; the
    /// estimator has its own lock, consulted OUTSIDE the state mutex)
    predictor: Option<SchedPredictor>,
    /// longest serveable conditioning prefix (the fleet's compiled
    /// seq_len); None = unknown, workers enforce it themselves
    max_prefix: Option<usize>,
    /// family assumed for requests that don't name one
    default_family: FamilyId,
    /// estimator-update ticks since the last bounded queue re-sort
    /// (the satellite re-sort is throttled, not per-completion)
    resort_ticks: AtomicU64,
    /// write-ahead admission journal (None = no durability); appended
    /// OUTSIDE the state lock, per the lock discipline
    journal: Option<Arc<Journal>>,
    /// worker-death retries allowed per request (0 = fail over to
    /// `unavailable` immediately, the pre-journal behavior)
    retry_budget: u32,
    /// brownout state machine armed?  Off by default: health stays
    /// `healthy` and nothing is ever shed
    health_enabled: bool,
    /// how long the raw health signal must stay clear before the
    /// machine steps down a level
    health_recover_ms: u64,
    /// latest evaluated health as u8, mirrored for lock-free reads on
    /// the worker hot path ([`Self::health_is_brownout`])
    health_atom: AtomicU8,
    /// admission-side bookkeeping: submissions, preflight completions,
    /// overload rejections, queued-side cancels and deadline drops
    pub metrics: Mutex<Metrics>,
}

impl Scheduler {
    /// `queue_cap` bounds the admission queue across all priority
    /// classes; `worker_families` names the family of each worker shard
    /// (index = worker id) that will pull from this scheduler.
    pub fn new(queue_cap: usize, worker_families: Vec<FamilyId>) -> Scheduler {
        let mut family_live = vec![0usize; crate::sampler::registry::count()];
        for f in &worker_families {
            tab_inc(&mut family_live, f.index());
        }
        let default_family = worker_families
            .first()
            .copied()
            .unwrap_or(Family::Ddlm.into());
        let n_workers = worker_families.len();
        Scheduler {
            state: Mutex::new(State {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                queued: 0,
                queued_by_family: vec![0; family_live.len()],
                queued_steps_by_family: vec![0; family_live.len()],
                running: HashMap::new(),
                cancel_flags: HashSet::new(),
                halt_flags: HashSet::new(),
                live_ids: HashSet::new(),
                workers_live: n_workers,
                family_live,
                worker_family: worker_families,
                worker_batch: vec![0; n_workers],
                worker_alive: vec![true; n_workers],
                rebind_orders: (0..n_workers).map(|_| None).collect(),
                shutdown: false,
                health: 0,
                health_clear_since: None,
            }),
            work_ready: Condvar::new(),
            queue_cap,
            class_caps: [usize::MAX; Priority::COUNT],
            family_caps: Vec::new(),
            predictor: None,
            max_prefix: None,
            default_family,
            resort_ticks: AtomicU64::new(0),
            journal: None,
            retry_budget: 0,
            health_enabled: false,
            health_recover_ms: 1500,
            health_atom: AtomicU8::new(0),
            metrics: Mutex::new(Metrics::default()),
        }
    }

    /// Hook up the write-ahead admission journal: every queued
    /// admission and every terminal resolution is appended (outside
    /// the state lock), so a restart can replay exactly the
    /// incomplete set.
    pub fn with_journal(mut self, journal: Arc<Journal>) -> Scheduler {
        self.journal = Some(journal);
        self
    }

    /// Allow each request up to `budget` re-admissions after a worker
    /// death (exponential backoff between attempts) before it fails
    /// over to the typed `unavailable`.  0 (the default) keeps the
    /// fail-fast behavior.
    pub fn with_retry_budget(mut self, budget: u32) -> Scheduler {
        self.retry_budget = budget;
        self
    }

    /// Arm the brownout state machine: escalate immediately on queue
    /// pressure or worker death, recover only after the signal stays
    /// clear for `recover_ms` (hysteresis).  Entering `browned_out`
    /// sheds low-priority queued work with a typed `overloaded`.
    pub fn with_brownout(mut self, recover_ms: u64) -> Scheduler {
        self.health_enabled = true;
        self.health_recover_ms = recover_ms;
        self
    }

    /// Reject requests whose prefix exceeds the fleet's compiled
    /// sequence length at admission, with a typed `invalid_request` —
    /// instead of letting a worker reject (or worse) deep in the stack.
    pub fn with_max_prefix(mut self, max: usize) -> Scheduler {
        self.max_prefix = Some(max);
        self
    }

    /// Family assumed for requests that don't carry one (the fleet
    /// default; `Scheduler::new` seeds it from the first worker).
    pub fn with_default_family(
        mut self,
        family: impl Into<FamilyId>,
    ) -> Scheduler {
        self.default_family = family.into();
        self
    }

    /// Per-priority-class queue bounds (high/normal/low, in
    /// `Priority::index()` order).  A class at its bound rejects with a
    /// typed `overloaded` while the other classes keep admitting.
    pub fn with_class_caps(
        mut self,
        caps: [usize; Priority::COUNT],
    ) -> Scheduler {
        self.class_caps = caps;
        self
    }

    /// Per-family queue caps (sparse: `(family, cap)` pairs; families
    /// not listed are unbounded beyond the shared `queue_cap`).  A
    /// family at its cap rejects with a typed `overloaded` while other
    /// families keep admitting — no head-of-line blocking across
    /// families.
    pub fn with_family_caps(
        mut self,
        caps: Vec<(FamilyId, usize)>,
    ) -> Scheduler {
        self.family_caps = caps;
        self
    }

    /// Hook up the fleet predictor: `admission` turns on the
    /// infeasible-deadline gate, `packing` picks the `next_for`
    /// discipline.  The estimator is shared with the workers (they
    /// feed it observations); it carries its own lock and is only ever
    /// consulted outside the scheduler's state mutex.
    pub fn with_predictor(
        mut self,
        est: Arc<Estimator>,
        admission: bool,
        packing: PackingMode,
    ) -> Scheduler {
        self.predictor = Some(SchedPredictor { est, admission, packing });
        self
    }

    /// Under the state lock: `worker`'s current family binding.
    fn family_in(&self, st: &State, worker: usize) -> FamilyId {
        st.worker_family
            .get(worker)
            .copied()
            .unwrap_or(self.default_family)
    }

    /// `worker`'s current family binding (rebinds re-point it live).
    pub fn family_of_worker(&self, worker: usize) -> FamilyId {
        let st = lock_or_recover(&self.state);
        self.family_in(&st, worker)
    }

    /// Admit one request.  Preflight-resolvable policies and zero-step
    /// budgets are answered inline (no queue slot, no device work) —
    /// but only on a live, accepting engine, so they can't sneak past
    /// shutdown or a dead fleet.  Rejections are typed: `Overloaded`
    /// (full queue or class, or draining engine), `Unavailable` (no
    /// workers), `InvalidRequest` (prefix longer than the compiled
    /// seq_len, or a family no live worker serves) and `DuplicateId`
    /// (id already queued or running) — the caller decides whether to
    /// surface them synchronously (`try_submit`) or through the reply
    /// channel.
    pub fn submit(
        &self,
        req: GenRequest,
        reply: ReplyTx,
    ) -> Result<(), ServeError> {
        self.submit_with_progress(req, reply, None)
    }

    /// [`Self::submit`] with an optional progress subscriber: the
    /// owning worker streams a [`ProgressEvent`] every
    /// `req.progress_every` executed steps to `progress` until the
    /// request finishes (the sender is dropped with the request, which
    /// is the subscriber's end-of-stream signal).
    pub fn submit_with_progress(
        &self,
        req: GenRequest,
        reply: ReplyTx,
        progress: Option<ProgressTx>,
    ) -> Result<(), ServeError> {
        lock_or_recover(&self.metrics).requests_submitted += 1;
        // wire-level validation first: an overlong prefix can never be
        // served (a worker's `reset_slot` would reject it anyway)
        if self.max_prefix.is_some_and(|max| req.prefix.len() > max) {
            lock_or_recover(&self.metrics).rejected_invalid += 1;
            return Err(ServeError::InvalidRequest);
        }
        let family = req.family.unwrap_or(self.default_family);
        // resolve the policy's preflight outside the state lock (policy
        // code is extensible; keep it out of the critical section); a
        // zero-step budget is equally answerable without a worker — its
        // schedule is exhausted before the first device step
        let pre = req.policy.preflight().reason();
        let immediate = pre.is_some() || req.n_steps == 0;
        let class = req.priority.index();

        // predictor consults happen here, BEFORE the admission lock:
        // the estimator has its own mutex and the lock discipline
        // (state mutex never nested with any other) must hold.  The
        // family's queued predicted-steps backlog — the expected queue
        // wait the feasibility check prices in — is snapshotted under a
        // brief state lock of its own, released before the estimator is
        // consulted (a race with a concurrent pop only makes the
        // snapshot conservative by one request).
        let (predicted_steps, infeasible) = match &self.predictor {
            Some(p) if !immediate => {
                let predicted =
                    Some(p.est.predict_total(family, req.n_steps).steps);
                let infeasible = p.admission
                    && req.deadline_ms.is_some_and(|d| {
                        let ahead = {
                            let st = lock_or_recover(&self.state);
                            tab_get(
                                &st.queued_steps_by_family,
                                family.index(),
                            )
                        };
                        matches!(
                            check_feasibility(
                                &p.est,
                                family,
                                req.n_steps,
                                ahead,
                                d,
                            ),
                            Feasibility::Infeasible { .. }
                        )
                    });
                (predicted, infeasible)
            }
            _ => (None, false),
        };

        // the journal's admit record is serialized BEFORE the lock
        // (JSON encoding has no place inside the critical section) and
        // appended after it, only when the request actually enqueued
        let admit_record = match &self.journal {
            Some(_) if !immediate => Some(req.to_json()),
            _ => None,
        };

        // admission verdict and enqueue under ONE lock acquisition: a
        // submit racing shutdown() or the last worker's exit must never
        // enqueue onto a fleet nobody will drain (the caller's recv()
        // would block forever on a reply that can't come)
        enum Admit {
            Immediate(GenRequest, ReplyTx),
            Enqueued,
            Reject(ServeError),
        }
        let (outcome, shed) = {
            let mut st = lock_or_recover(&self.state);
            let outcome = if st.workers_live == 0 {
                Admit::Reject(ServeError::Unavailable)
            } else if st.shutdown {
                Admit::Reject(ServeError::Overloaded)
            } else if tab_get(&st.family_live, family.index()) == 0 {
                // no live worker runs this family's kernel: the fleet
                // can never serve it — typed rejection, even for
                // preflight-resolvable requests (consistency: an
                // unserveable request is invalid, not answerable)
                Admit::Reject(ServeError::InvalidRequest)
            } else if st.live_ids.contains(&req.id) {
                // checked before the immediate path too: answering a
                // zero-step resubmission of a live id would emit two
                // completions for one id
                Admit::Reject(ServeError::DuplicateId)
            } else if immediate {
                Admit::Immediate(req, reply)
            } else if infeasible {
                // predicted wall time exceeds the request's own
                // deadline: reject up front instead of burning device
                // steps on a guaranteed `deadline_exceeded`
                Admit::Reject(ServeError::InfeasibleDeadline)
            } else if st.queued >= self.queue_cap
                || st.queues[class].len() >= self.class_caps[class]
            {
                Admit::Reject(ServeError::Overloaded)
            } else if self
                .family_caps
                .iter()
                .find(|(f, _)| *f == family)
                .is_some_and(|&(_, cap)| {
                    tab_get(&st.queued_by_family, family.index()) >= cap
                })
            {
                Admit::Reject(ServeError::Overloaded)
            } else {
                st.live_ids.insert(req.id);
                let id = req.id;
                let q = QueuedReq::new(
                    req,
                    Reply {
                        tx: reply,
                        journal: self.journal.clone(),
                        id,
                    },
                    progress,
                    family,
                    predicted_steps,
                );
                let cost = queued_cost(&q);
                st.queues[class].push_back(q);
                st.queued += 1;
                tab_inc(&mut st.queued_by_family, family.index());
                tab_add(
                    &mut st.queued_steps_by_family,
                    family.index(),
                    cost,
                );
                // the admit record must land before the state lock
                // releases: a worker popping the instant it unlocks
                // would otherwise journal the resolve ahead of the
                // admit, and replay would resurrect a resolved
                // request.  (state → journal nesting is
                // one-directional; nothing acquires state under the
                // journal's lock.)
                if let (Some(j), Some(rec)) =
                    (&self.journal, admit_record)
                {
                    j.admit_json(rec);
                }
                Admit::Enqueued
            };
            let shed = self.eval_health_locked(&mut st);
            (outcome, shed)
        };
        self.resolve_shed(shed);
        match outcome {
            Admit::Enqueued => {
                self.work_ready.notify_all();
                Ok(())
            }
            Admit::Immediate(req, reply) => {
                let mut resp = GenResponse::immediate(&req, pre);
                resp.family = Some(family);
                lock_or_recover(&self.metrics).record_completion(
                    &resp,
                    req.priority,
                    family,
                );
                let _ = reply.send(Ok(resp));
                Ok(())
            }
            Admit::Reject(e) => {
                let mut m = lock_or_recover(&self.metrics);
                match e {
                    ServeError::Overloaded => m.rejected_overloaded += 1,
                    ServeError::InfeasibleDeadline => {
                        m.rejected_infeasible += 1
                    }
                    ServeError::DuplicateId | ServeError::InvalidRequest => {
                        m.rejected_invalid += 1
                    }
                    _ => {}
                }
                Err(e)
            }
        }
    }

    /// Pop the next runnable request for `worker` (high before normal
    /// before low, FIFO within a class — or
    /// shortest-predicted-remaining-first under SRPT packing,
    /// restricted to the worker's family), answering and removing
    /// queued requests whose deadline already expired along the way.
    pub fn next_for(&self, worker: usize) -> Option<QueuedReq> {
        let srpt = self
            .predictor
            .as_ref()
            .is_some_and(|p| p.packing == PackingMode::Srpt);
        let now = Instant::now();
        let mut expired: Vec<QueuedReq> = Vec::new();
        let picked = {
            let mut st = lock_or_recover(&self.state);
            let fam = self.family_in(&st, worker);
            // anti-ping-pong: a migrated slot avoids the worker it just
            // left — but only while another live worker serves the
            // family (a last-worker-standing must still take it back)
            let others = tab_get(&st.family_live, fam.index()) >= 2;
            let mut picked = None;
            'scan: for pi in 0..Priority::COUNT {
                // under SRPT, the whole class is scanned and the
                // family match with the fewest predicted remaining
                // steps wins (strict `<` keeps ties FIFO-stable);
                // under FIFO the first match wins, as ever.  A request
                // admitted without a prediction (predictor added
                // mid-flight) counts its full budget.
                let mut best: Option<(usize, usize)> = None;
                let mut k = 0;
                while k < st.queues[pi].len() {
                    if st.queues[pi][k].deadline.is_some_and(|d| now >= d) {
                        // remove(k) is Some: k < len by the loop guard
                        let Some(q) = st.queues[pi].remove(k) else {
                            break;
                        };
                        st.queued -= 1;
                        tab_dec(&mut st.queued_by_family, q.family.index());
                        tab_sub(
                            &mut st.queued_steps_by_family,
                            q.family.index(),
                            queued_cost(&q),
                        );
                        st.live_ids.remove(&q.req.id);
                        expired.push(q);
                        // `best` indexes an earlier position (< k), so
                        // this removal at k never shifts it
                        continue;
                    }
                    let q = &st.queues[pi][k];
                    // retry backoff: skip (don't remove) entries whose
                    // re-admission instant hasn't arrived yet
                    if q.not_before.is_some_and(|t| now < t) {
                        k += 1;
                        continue;
                    }
                    let bounced = others
                        && q.resume
                            .as_ref()
                            .is_some_and(|r| r.migrated_from == Some(worker));
                    if q.family == fam && !bounced {
                        if !srpt {
                            best = Some((k, 0));
                            break;
                        }
                        let pred = queued_cost(q);
                        let better = match best {
                            None => true,
                            Some((_, b)) => pred < b,
                        };
                        if better {
                            best = Some((k, pred));
                        }
                    }
                    k += 1;
                }
                if let Some((k, _)) = best {
                    // remove(k) is Some: `best` indexes a scanned entry
                    let Some(q) = st.queues[pi].remove(k) else {
                        break 'scan;
                    };
                    st.queued -= 1;
                    tab_dec(&mut st.queued_by_family, fam.index());
                    tab_sub(
                        &mut st.queued_steps_by_family,
                        fam.index(),
                        queued_cost(&q),
                    );
                    st.running.insert(q.req.id, worker);
                    picked = Some(q);
                    break 'scan;
                }
            }
            picked
        };
        if !expired.is_empty() {
            let mut m = lock_or_recover(&self.metrics);
            m.deadline_exceeded += expired.len() as u64;
            drop(m);
            for q in expired {
                let _ = q.reply.send(Err(ServeError::DeadlineExceeded));
            }
        }
        picked
    }

    /// Answer and drop every queued request whose deadline has expired.
    /// Workers call this once per step loop, so a request that can't be
    /// admitted in time is answered within one device-step latency even
    /// while every slot is busy (not just lazily at pop time).
    pub fn reap_expired(&self) {
        let now = Instant::now();
        let expired = {
            let mut st = lock_or_recover(&self.state);
            let mut expired = Vec::new();
            for q in st.queues.iter_mut() {
                let mut k = 0;
                while k < q.len() {
                    if q[k].deadline.is_some_and(|d| now >= d) {
                        // remove(k) is Some: k < q.len() by the loop guard
                        expired.extend(q.remove(k));
                    } else {
                        k += 1;
                    }
                }
            }
            st.queued -= expired.len();
            for q in &expired {
                tab_dec(&mut st.queued_by_family, q.family.index());
                tab_sub(
                    &mut st.queued_steps_by_family,
                    q.family.index(),
                    queued_cost(q),
                );
                st.live_ids.remove(&q.req.id);
            }
            expired
        };
        if !expired.is_empty() {
            lock_or_recover(&self.metrics).deadline_exceeded +=
                expired.len() as u64;
            for q in expired {
                let _ = q.reply.send(Err(ServeError::DeadlineExceeded));
            }
        }
    }

    /// Cancel by request id: a queued request is removed and answered
    /// here; a running one is flagged for its worker.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let (outcome, victim) = {
            let mut st = lock_or_recover(&self.state);
            let mut victim = None;
            for pi in 0..Priority::COUNT {
                if let Some(k) =
                    st.queues[pi].iter().position(|q| q.req.id == id)
                {
                    victim = st.queues[pi].remove(k);
                    st.queued -= 1;
                    break;
                }
            }
            if let Some(q) = &victim {
                tab_dec(&mut st.queued_by_family, q.family.index());
                tab_sub(
                    &mut st.queued_steps_by_family,
                    q.family.index(),
                    queued_cost(q),
                );
                st.live_ids.remove(&q.req.id);
                (CancelOutcome::Queued, victim)
            } else if st.running.contains_key(&id) {
                st.cancel_flags.insert(id);
                (CancelOutcome::Running, None)
            } else {
                (CancelOutcome::NotFound, None)
            }
        };
        if let Some(q) = victim {
            lock_or_recover(&self.metrics).cancelled += 1;
            let _ = q.reply.send(Err(ServeError::Cancelled));
        }
        outcome
    }

    /// Gracefully finalize a request by id — the client-visible form of
    /// the paper's early exit, distinct from [`Self::cancel`]: the
    /// submitter receives a *normal* completion with
    /// `halt_reason:"client"`, never an error.  A queued request (no
    /// steps executed yet) is answered here with an empty zero-step
    /// decode; a running one is flagged so its owning worker finalizes
    /// it with the current x0 decode between device steps.
    pub fn halt(&self, id: u64) -> CancelOutcome {
        let (outcome, victim) = {
            let mut st = lock_or_recover(&self.state);
            let mut victim = None;
            for pi in 0..Priority::COUNT {
                if let Some(k) =
                    st.queues[pi].iter().position(|q| q.req.id == id)
                {
                    victim = st.queues[pi].remove(k);
                    st.queued -= 1;
                    break;
                }
            }
            if let Some(q) = &victim {
                tab_dec(&mut st.queued_by_family, q.family.index());
                tab_sub(
                    &mut st.queued_steps_by_family,
                    q.family.index(),
                    queued_cost(q),
                );
                st.live_ids.remove(&q.req.id);
                (CancelOutcome::Queued, victim)
            } else if st.running.contains_key(&id) {
                st.halt_flags.insert(id);
                (CancelOutcome::Running, None)
            } else {
                (CancelOutcome::NotFound, None)
            }
        };
        if let Some(q) = victim {
            // still queued = zero steps executed: the "current decode"
            // is empty, and the whole budget counts as saved
            let mut resp = GenResponse::immediate(&q.req, Some("client"));
            resp.family = Some(q.family);
            resp.queue_ms = q.submitted.elapsed().as_secs_f64() * 1e3;
            resp.latency_ms = resp.queue_ms;
            lock_or_recover(&self.metrics).record_completion(
                &resp,
                q.req.priority,
                q.family,
            );
            let _ = q.reply.send(Ok(resp));
        }
        outcome
    }

    /// Worker-side: has this running request been flagged for abort?
    pub fn cancel_requested(&self, id: u64) -> bool {
        lock_or_recover(&self.state).cancel_flags.contains(&id)
    }

    /// Worker-side: has this running request been flagged for a
    /// graceful client halt?  (An explicit cancel outranks a graceful
    /// halt.)
    pub fn halt_requested(&self, id: u64) -> bool {
        lock_or_recover(&self.state).halt_flags.contains(&id)
    }

    /// Worker-side: both flag checks under ONE lock acquisition — the
    /// per-slot sweep runs every device step, so checking cancel and
    /// halt separately would double the hot loop's traffic on the
    /// state mutex.  Cancel outranks halt.
    pub fn flagged(&self, id: u64) -> Option<Flagged> {
        let st = lock_or_recover(&self.state);
        Self::flagged_in(&st, id)
    }

    /// Worker-side: the whole sweep's flag checks under ONE lock
    /// acquisition — the per-id [`Self::flagged`] costs one scheduler
    /// lock per occupied slot per loop iteration, which at batch 8 is
    /// 8x the necessary traffic on the state mutex.  Returns the
    /// verdicts in `ids` order; cancel outranks halt.
    pub fn flagged_sweep(&self, ids: &[u64]) -> Vec<Option<Flagged>> {
        let mut out = Vec::new();
        self.flagged_sweep_into(ids, &mut out);
        out
    }

    /// [`Self::flagged_sweep`] into caller-owned scratch (cleared
    /// first) — the worker's steady loop reuses one buffer so the
    /// sweep allocates nothing per iteration.
    pub fn flagged_sweep_into(
        &self,
        ids: &[u64],
        out: &mut Vec<Option<Flagged>>,
    ) {
        out.clear();
        if ids.is_empty() {
            return;
        }
        let st = lock_or_recover(&self.state);
        out.extend(ids.iter().map(|&id| Self::flagged_in(&st, id)));
    }

    fn flagged_in(st: &State, id: u64) -> Option<Flagged> {
        if st.cancel_flags.contains(&id) {
            Some(Flagged::Cancel)
        } else if st.halt_flags.contains(&id) {
            Some(Flagged::Halt)
        } else {
            None
        }
    }

    /// Worker-side: a request left the running set (completed, aborted,
    /// halted, or deadline-dropped).
    pub fn finish(&self, id: u64) {
        let mut st = lock_or_recover(&self.state);
        st.running.remove(&id);
        st.cancel_flags.remove(&id);
        st.halt_flags.remove(&id);
        st.live_ids.remove(&id);
    }

    /// Block until work this worker's family can serve is queued
    /// (`Work`), a rebind order lands for it (`Rebind`), or the engine
    /// is shut down with a drained queue (`Exit`).  Only fully-idle
    /// workers wait here; busy workers are driven by their own step
    /// loop.  The predicate is per-family so a worker never busy-wakes
    /// on work only another kernel can serve — and it re-reads the
    /// family each pass, because a rebind changes it.
    pub fn wait_for_work(&self, worker: usize) -> IdleWait {
        let mut st = lock_or_recover(&self.state);
        loop {
            if st
                .rebind_orders
                .get(worker)
                .is_some_and(Option::is_some)
            {
                return IdleWait::Rebind;
            }
            let fam = self.family_in(&st, worker);
            let mut backoff_only = false;
            if tab_get(&st.queued_by_family, fam.index()) > 0 {
                // a queue holding ONLY backoff-delayed retries must
                // not return `Work` (next_for would spin on it) nor
                // sleep untimed (nobody notifies when a backoff
                // expires) — take a short timed wait instead
                let now = Instant::now();
                let ready = st.queues.iter().flatten().any(|q| {
                    q.family == fam
                        && !q.not_before.is_some_and(|t| now < t)
                });
                if ready {
                    return IdleWait::Work;
                }
                backoff_only = true;
            }
            if st.shutdown {
                return IdleWait::Exit;
            }
            st = if backoff_only {
                wait_timeout_or_recover(
                    &self.work_ready,
                    st,
                    Duration::from_millis(5),
                )
            } else {
                wait_or_recover(&self.work_ready, st)
            };
        }
    }

    /// Stop admitting; idle workers wake, drain the queue, and exit.
    pub fn shutdown(&self) {
        lock_or_recover(&self.state).shutdown = true;
        self.work_ready.notify_all();
    }

    /// `worker` exited (normally, on error, or by panic).  Its running
    /// state is purged — a panic skips the per-request `finish()` calls,
    /// and stale entries would reject future reuse of those ids as
    /// duplicates forever.  When the last worker of a *family* goes
    /// with that family's requests still queued, they fail over to
    /// `Unavailable` so submitters never block on work nobody will
    /// drain (other families' shards keep serving their own queues).
    pub fn worker_down(&self, worker: usize) {
        let (orphans, aborted_order) = {
            let mut st = lock_or_recover(&self.state);
            let fam = self.family_in(&st, worker);
            st.workers_live = st.workers_live.saturating_sub(1);
            if let Some(a) = st.worker_alive.get_mut(worker) {
                *a = false;
            }
            // a rebind order nobody will ever take fails typed, not
            // silently (its requester is blocked on the reply)
            let order =
                st.rebind_orders.get_mut(worker).and_then(Option::take);
            tab_dec(&mut st.family_live, fam.index());
            let dead: Vec<u64> = st
                .running
                .iter()
                .filter_map(|(id, w)| (*w == worker).then_some(*id))
                .collect();
            for id in dead {
                st.running.remove(&id);
                st.cancel_flags.remove(&id);
                st.halt_flags.remove(&id);
                st.live_ids.remove(&id);
            }
            (drain_family_if_dead(&mut st, fam), order)
        };
        if let Some(o) = aborted_order {
            if let Some(tx) = o.reply {
                let _ = tx.send(Err("worker exited before rebind".into()));
            }
        }
        for q in orphans {
            let _ = q.reply.send(Err(ServeError::Unavailable));
        }
    }

    /// A worker lost `q` mid-flight (panic or device failure).  With
    /// retry budget left and another live worker serving the family,
    /// the request is re-admitted (fresh slot, exponential backoff,
    /// its id stays live) and `None` is returned; otherwise the
    /// request is handed back for the caller to answer
    /// `Err(Unavailable)`.  Replaces the `finish()` + error-send pair
    /// on the worker fail-over paths.
    pub fn fail_running(
        &self,
        worker: usize,
        mut q: QueuedReq,
    ) -> Option<QueuedReq> {
        let mut out = None;
        let retried = {
            let mut st = lock_or_recover(&self.state);
            let id = q.req.id;
            st.running.remove(&id);
            st.cancel_flags.remove(&id);
            st.halt_flags.remove(&id);
            let peer_alive = st.worker_family.iter().enumerate().any(
                |(w, &f)| {
                    w != worker
                        && f == q.family
                        && st.worker_alive.get(w).copied().unwrap_or(false)
                },
            );
            if q.attempts < self.retry_budget && !st.shutdown && peer_alive
            {
                q.attempts += 1;
                // the slot's device state died with the worker: restart
                // from the recorded params, not a resume import
                q.resume = None;
                let shift = (q.attempts - 1).min(6);
                q.not_before = Some(
                    Instant::now()
                        + Duration::from_millis(10u64 << shift),
                );
                let class = q.req.priority.index();
                st.queued += 1;
                tab_inc(&mut st.queued_by_family, q.family.index());
                tab_add(
                    &mut st.queued_steps_by_family,
                    q.family.index(),
                    queued_cost(&q),
                );
                st.queues[class].push_back(q);
                true
            } else {
                // terminal: the id leaves the live set exactly as
                // `finish()` would have removed it
                st.live_ids.remove(&id);
                out = Some(q);
                false
            }
        };
        if retried {
            lock_or_recover(&self.metrics).requests_retried += 1;
            self.work_ready.notify_all();
        } else if self.retry_budget > 0
            && out
                .as_ref()
                .is_some_and(|q| q.attempts >= self.retry_budget)
        {
            lock_or_recover(&self.metrics).retries_exhausted += 1;
        }
        out
    }

    /// Evaluate (and possibly transition) the brownout machine, then
    /// report the fleet's health.  Callable from anywhere — the error
    /// frame encoder and the metrics snapshot both re-evaluate, so
    /// recovery shows without waiting for traffic.
    pub fn health(&self) -> FleetHealth {
        let (h, shed) = {
            let mut st = lock_or_recover(&self.state);
            let shed = self.eval_health_locked(&mut st);
            (st.health, shed)
        };
        self.resolve_shed(shed);
        FleetHealth::from_u8(h)
    }

    /// Lock-free health read for the worker hot path (may lag the
    /// last evaluation by one transition; the hysteresis window is
    /// orders of magnitude longer).
    pub fn health_is_brownout(&self) -> bool {
        self.health_atom.load(Ordering::Relaxed) == 2
    }

    /// Whether the brownout machine is armed at all — the metrics
    /// snapshot emits `fleet_health` only then, so unarmed snapshots
    /// keep their exact key set.
    pub fn brownout_enabled(&self) -> bool {
        self.health_enabled
    }

    /// Under the state lock: recompute the raw health signal, apply
    /// the hysteresis, and on a transition *into* brownout strip the
    /// low-priority queue.  Victims are returned for the caller to
    /// answer outside the lock.
    fn eval_health_locked(&self, st: &mut State) -> Vec<QueuedReq> {
        if !self.health_enabled {
            return Vec::new();
        }
        let pressure = |pct: usize| {
            self.queue_cap > 0
                && st.queued.saturating_mul(100)
                    >= self.queue_cap.saturating_mul(pct)
        };
        let raw: u8 = if pressure(90) {
            2
        } else if pressure(60) || st.worker_alive.iter().any(|a| !a) {
            1
        } else {
            0
        };
        let prev = st.health;
        let mut shed = Vec::new();
        if raw > prev {
            // escalate immediately; entering brownout sheds the whole
            // low-priority queue (head-of-line work survives, optional
            // work is suspended by the workers' atom reads)
            st.health = raw;
            st.health_clear_since = None;
            if raw == 2 {
                let li = Priority::Low.index();
                while let Some(q) = st.queues[li].pop_front() {
                    st.queued -= 1;
                    tab_dec(&mut st.queued_by_family, q.family.index());
                    tab_sub(
                        &mut st.queued_steps_by_family,
                        q.family.index(),
                        queued_cost(&q),
                    );
                    st.live_ids.remove(&q.req.id);
                    shed.push(q);
                }
            }
        } else if raw < prev {
            // de-escalate only after the signal stays clear for the
            // recovery window — no flapping at a threshold boundary
            let now = Instant::now();
            match st.health_clear_since {
                None => st.health_clear_since = Some(now),
                Some(t)
                    if now.duration_since(t)
                        >= Duration::from_millis(
                            self.health_recover_ms,
                        ) =>
                {
                    st.health = raw;
                    st.health_clear_since = None;
                }
                Some(_) => {}
            }
        } else {
            st.health_clear_since = None;
        }
        self.health_atom.store(st.health, Ordering::Relaxed);
        shed
    }

    /// Answer brownout-shed requests (outside the state lock) with the
    /// typed `overloaded` and count them.
    fn resolve_shed(&self, shed: Vec<QueuedReq>) {
        if shed.is_empty() {
            return;
        }
        lock_or_recover(&self.metrics).brownout_shed += shed.len() as u64;
        for q in shed {
            let _ = q.reply.send(Err(ServeError::Overloaded));
        }
    }

    /// Current admission-queue depth (fleet gauge).
    pub fn queue_depth(&self) -> usize {
        lock_or_recover(&self.state).queued
    }

    /// Whether `shutdown()` has been called (supervisor exit signal).
    pub fn is_shutdown(&self) -> bool {
        lock_or_recover(&self.state).shutdown
    }

    /// Requests admitted to a worker and not yet finished (fleet gauge).
    pub fn running_count(&self) -> usize {
        lock_or_recover(&self.state).running.len()
    }

    /// Predicted steps queued ahead for a family — the backlog the
    /// admission gate prices as expected queue wait.
    pub fn queued_steps_for(&self, family: impl Into<FamilyId>) -> usize {
        let family = family.into();
        let st = lock_or_recover(&self.state);
        tab_get(&st.queued_steps_by_family, family.index())
    }

    // ------------------------------------------------------------------
    // elastic fleet: drain → rebind → rejoin, and live slot migration
    // ------------------------------------------------------------------

    /// Post a drain→rebind→rejoin order for `worker`.  The order is
    /// taken exactly once by the owning worker (idle workers wake on
    /// it; busy workers notice it at the top of their step loop).
    /// Typed refusals: an unknown or exited worker, a draining engine,
    /// and one order already in flight.
    pub fn request_rebind(
        &self,
        worker: usize,
        order: RebindOrder,
    ) -> Result<(), &'static str> {
        {
            let mut st = lock_or_recover(&self.state);
            if worker >= st.worker_family.len() {
                return Err("unknown_worker");
            }
            if !st.worker_alive.get(worker).copied().unwrap_or(false) {
                return Err("worker_down");
            }
            if st.shutdown {
                return Err("shutting_down");
            }
            if st.rebind_orders[worker].is_some() {
                return Err("rebind_in_flight");
            }
            st.rebind_orders[worker] = Some(order);
        }
        self.work_ready.notify_all();
        Ok(())
    }

    /// Worker-side: claim this worker's pending rebind order, if any.
    pub fn take_rebind(&self, worker: usize) -> Option<RebindOrder> {
        lock_or_recover(&self.state)
            .rebind_orders
            .get_mut(worker)
            .and_then(Option::take)
    }

    /// Is a rebind order pending for `worker`?  (Supervisor cooldown
    /// check; the worker itself uses [`Self::take_rebind`].)
    pub fn rebind_pending(&self, worker: usize) -> bool {
        let st = lock_or_recover(&self.state);
        st.rebind_orders.get(worker).is_some_and(Option::is_some)
    }

    /// Worker-side: push drained in-flight requests back to the *front*
    /// of their class queues (original admission order preserved), with
    /// their mid-generation [`ResumeState`] attached.  The ids stay
    /// live — these requests were admitted once and must complete
    /// exactly once; nothing here can reject them.
    pub fn requeue_drained(&self, items: Vec<QueuedReq>) {
        if items.is_empty() {
            return;
        }
        {
            let mut st = lock_or_recover(&self.state);
            for q in items.into_iter().rev() {
                st.running.remove(&q.req.id);
                let class = q.req.priority.index();
                st.queued += 1;
                tab_inc(&mut st.queued_by_family, q.family.index());
                tab_add(
                    &mut st.queued_steps_by_family,
                    q.family.index(),
                    queued_cost(&q),
                );
                st.queues[class].push_front(q);
            }
        }
        self.work_ready.notify_all();
    }

    /// Worker-side: the rebind finished — re-point the routing table to
    /// the worker's new `(family, batch)` binding.  When the *old*
    /// family just lost its last live worker, its queued requests fail
    /// over to `Unavailable` exactly like a worker exit (submitters are
    /// answered, never hung).
    pub fn complete_rebind(
        &self,
        worker: usize,
        family: FamilyId,
        batch: usize,
    ) {
        let orphans = {
            let mut st = lock_or_recover(&self.state);
            if worker >= st.worker_family.len() {
                return;
            }
            let old = st.worker_family[worker];
            st.worker_family[worker] = family;
            if let Some(b) = st.worker_batch.get_mut(worker) {
                *b = batch;
            }
            if old == family {
                Vec::new()
            } else {
                tab_dec(&mut st.family_live, old.index());
                // family_live is a plain counter table like the others
                if family.index() >= st.family_live.len() {
                    st.family_live.resize(family.index() + 1, 0);
                }
                st.family_live[family.index()] += 1;
                drain_family_if_dead(&mut st, old)
            }
        };
        for q in orphans {
            let _ = q.reply.send(Err(ServeError::Unavailable));
        }
        // the new family's queued work (if any) can now be served here
        self.work_ready.notify_all();
    }

    /// Worker-side: report the resolved compiled batch (at startup and
    /// after every rebind) — the migration policy's shard-size signal.
    pub fn register_worker_batch(&self, worker: usize, batch: usize) {
        let mut st = lock_or_recover(&self.state);
        if let Some(b) = st.worker_batch.get_mut(worker) {
            *b = batch;
        }
    }

    /// Is a live worker of `family` bound to a strictly smaller batch
    /// than `worker`'s — i.e. is there a smaller shard a mostly-frozen
    /// long-tail slot could migrate to?  Workers with a rebind in
    /// flight don't count (their binding is about to change).
    pub fn smaller_shard_live(&self, worker: usize, family: FamilyId) -> bool {
        let st = lock_or_recover(&self.state);
        let my_b = st.worker_batch.get(worker).copied().unwrap_or(0);
        if my_b == 0 {
            return false;
        }
        st.worker_family.iter().enumerate().any(|(w, &f)| {
            let b = st.worker_batch.get(w).copied().unwrap_or(0);
            w != worker
                && f == family
                && st.worker_alive.get(w).copied().unwrap_or(false)
                && st.rebind_orders.get(w).map_or(true, Option::is_none)
                && b > 0
                && b < my_b
        })
    }

    /// One consistent view of the fleet for the `--fleet auto`
    /// supervisor: every worker's binding and load, plus the queued
    /// backlog per family.
    pub fn fleet_snapshot(&self) -> FleetSnapshot {
        let st = lock_or_recover(&self.state);
        let mut load = vec![0usize; st.worker_family.len()];
        for &w in st.running.values() {
            if let Some(v) = load.get_mut(w) {
                *v += 1;
            }
        }
        FleetSnapshot {
            workers: st
                .worker_family
                .iter()
                .enumerate()
                .map(|(w, &family)| WorkerInfo {
                    worker: w,
                    family,
                    batch: st.worker_batch.get(w).copied().unwrap_or(0),
                    alive: st.worker_alive.get(w).copied().unwrap_or(false),
                    running: load[w],
                    rebind_pending: st
                        .rebind_orders
                        .get(w)
                        .is_some_and(Option::is_some),
                })
                .collect(),
            queued_by_family: st.queued_by_family.clone(),
        }
    }

    // ------------------------------------------------------------------
    // estimator-shift re-sort (bounded, throttled)
    // ------------------------------------------------------------------

    /// The estimator learned something (a worker fed it a completion).
    /// Every [`RESORT_PERIOD`]-th call re-prices and re-sorts the front
    /// of the queues — predictions admitted early in a burst go stale
    /// as the estimator trains, and SRPT packed on stale predictions is
    /// just FIFO with extra steps.
    pub fn note_estimator_update(&self) {
        if self.predictor.is_none() {
            return;
        }
        let n = self.resort_ticks.fetch_add(1, Ordering::Relaxed) + 1;
        if n % RESORT_PERIOD == 0 {
            self.resort_queues();
        }
    }

    /// Re-price the first [`RESORT_BOUND`] queued requests of every
    /// class against the estimator's *current* predictions, fix the
    /// per-family backlog tables, and (under SRPT packing) stable-sort
    /// each re-priced front segment — stable, so equal predictions keep
    /// their FIFO order.  Bounded: a deep queue's tail keeps its order
    /// and its admission-time predictions until it reaches the front.
    pub fn resort_queues(&self) {
        let Some(p) = &self.predictor else { return };
        // snapshot the front segments under the lock, consult the
        // estimator OUTSIDE it (lock discipline: the estimator's mutex
        // is never nested inside the state mutex)
        let snapshot: Vec<(u64, FamilyId, usize)> = {
            let st = lock_or_recover(&self.state);
            st.queues
                .iter()
                .flat_map(|q| {
                    q.iter().take(RESORT_BOUND).filter_map(|q| {
                        // resumed requests are priced by their actual
                        // remaining schedule, not a prediction
                        q.resume.is_none().then(|| {
                            (q.req.id, q.family, q.req.n_steps)
                        })
                    })
                })
                .collect()
        };
        if snapshot.is_empty() {
            return;
        }
        let preds: HashMap<u64, usize> = snapshot
            .into_iter()
            .map(|(id, fam, n)| (id, p.est.predict_total(fam, n).steps))
            .collect();
        let srpt = p.packing == PackingMode::Srpt;
        let mut st = lock_or_recover(&self.state);
        let State { queues, queued_steps_by_family, .. } = &mut *st;
        for q in queues.iter_mut() {
            let bound = q.len().min(RESORT_BOUND);
            for k in 0..bound {
                let item = &mut q[k];
                // items may have moved since the snapshot (a concurrent
                // pop); match by id and skip the missing
                let Some(&newp) = preds.get(&item.req.id) else {
                    continue;
                };
                let old = queued_cost(item);
                item.predicted_steps = Some(newp);
                let newc = queued_cost(item);
                if newc != old {
                    tab_sub(queued_steps_by_family, item.family.index(), old);
                    tab_add(queued_steps_by_family, item.family.index(), newc);
                }
            }
            if srpt && bound > 1 {
                let mut rest = q.split_off(bound);
                let mut front: Vec<QueuedReq> = q.drain(..).collect();
                front.sort_by_key(queued_cost);
                q.extend(front);
                q.append(&mut rest);
            }
        }
    }
}

/// Re-sort cadence: one bounded re-sort per this many estimator
/// updates.  Completions arrive per request; re-sorting each one would
/// make queue order churn O(completions × queue depth).
pub const RESORT_PERIOD: u64 = 8;

/// How deep into each class queue a re-sort re-prices and re-orders.
pub const RESORT_BOUND: usize = 64;

/// One worker's binding and load in a [`Scheduler::fleet_snapshot`].
#[derive(Clone, Copy, Debug)]
pub struct WorkerInfo {
    pub worker: usize,
    pub family: FamilyId,
    pub batch: usize,
    pub alive: bool,
    /// requests currently admitted to this worker
    pub running: usize,
    pub rebind_pending: bool,
}

/// Consistent fleet view for the `--fleet auto` supervisor.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub workers: Vec<WorkerInfo>,
    /// queued requests per family (indexed by `FamilyId::index()`)
    pub queued_by_family: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halting::parse_policy;

    fn req(id: u64, steps: usize) -> GenRequest {
        GenRequest::new(id, steps)
    }

    fn chan() -> (ReplyTx, mpsc::Receiver<Result<GenResponse, ServeError>>) {
        mpsc::channel()
    }

    fn sched(queue_cap: usize, workers: usize) -> Scheduler {
        Scheduler::new(queue_cap, vec![Family::Ddlm.into(); workers])
    }

    fn fleet(families: &[Family]) -> Vec<FamilyId> {
        families.iter().map(|&f| f.into()).collect()
    }

    #[test]
    fn bounded_queue_rejects_overloaded() {
        let s = sched(2, 1);
        for id in 0..2 {
            let (tx, _rx) = chan();
            assert!(s.submit(req(id, 10), tx).is_ok());
        }
        let (tx, rx) = chan();
        assert_eq!(s.submit(req(9, 10), tx), Err(ServeError::Overloaded));
        assert_eq!(s.queue_depth(), 2);
        assert_eq!(lock_or_recover(&s.metrics).rejected_overloaded, 1);
        assert_eq!(lock_or_recover(&s.metrics).requests_submitted, 3);
        // the sync rejection never uses the reply channel
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn class_bound_rejects_full_class_without_starving_others() {
        // global cap is roomy; the low class alone is capped at 1
        let s = sched(16, 1).with_class_caps([usize::MAX, usize::MAX, 1]);
        let mut low = req(1, 10);
        low.priority = Priority::Low;
        let (tx, _rx) = chan();
        assert!(s.submit(low, tx).is_ok());
        // the low class is full: typed overload, no reply traffic
        let mut low2 = req(2, 10);
        low2.priority = Priority::Low;
        let (tx2, rx2) = chan();
        assert_eq!(s.submit(low2, tx2), Err(ServeError::Overloaded));
        assert!(rx2.try_recv().is_err());
        assert_eq!(lock_or_recover(&s.metrics).rejected_overloaded, 1);
        // ...but normal and high traffic still admits
        for (id, prio) in [(3, Priority::Normal), (4, Priority::High)] {
            let mut r = req(id, 10);
            r.priority = prio;
            let (tx, _rx) = chan();
            assert!(s.submit(r, tx).is_ok(), "{prio:?} starved");
        }
        assert_eq!(s.queue_depth(), 3);
        // draining the low class frees its slot again
        assert_eq!(s.next_for(0).unwrap().req.id, 4);
        assert_eq!(s.next_for(0).unwrap().req.id, 3);
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
        let mut low3 = req(5, 10);
        low3.priority = Priority::Low;
        let (tx3, _rx3) = chan();
        assert!(s.submit(low3, tx3).is_ok());
    }

    #[test]
    fn preflight_resolves_without_consuming_queue() {
        let s = sched(1, 1);
        let (tx, rx) = chan();
        let mut r = req(7, 25);
        r.policy = parse_policy("fixed:0").unwrap();
        s.submit(r, tx).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.steps_executed, 0);
        assert_eq!(resp.halt_reason.as_deref(), Some("fixed"));
        // the immediate path resolves the family too
        assert_eq!(resp.family, Some(Family::Ddlm.into()));
        assert_eq!(s.queue_depth(), 0);
        let m = lock_or_recover(&s.metrics);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.steps_saved, 25);
        assert_eq!(m.halted_by.get("fixed"), Some(&1));
        // the unified path observes the latency/queue histograms too
        assert_eq!(m.latency_ms.count(), 1);
        assert_eq!(m.queue_ms.count(), 1);
    }

    #[test]
    fn workers_drain_priority_classes_in_order() {
        let s = sched(16, 1);
        for (id, prio) in
            [(1, Priority::Low), (2, Priority::Normal), (3, Priority::High)]
        {
            let (tx, _rx) = chan();
            let mut r = req(id, 10);
            r.priority = prio;
            s.submit(r, tx).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| s.next_for(0))
            .map(|q| q.req.id)
            .collect();
        assert_eq!(order, vec![3, 2, 1]);
        assert_eq!(s.running_count(), 3);
    }

    #[test]
    fn requests_route_only_to_matching_family_workers() {
        // worker 0 = ddlm, worker 1 = ssd
        let s = Scheduler::new(16, fleet(&[Family::Ddlm, Family::Ssd]));
        for (id, fam) in [
            (1, Family::Ddlm),
            (2, Family::Ssd),
            (3, Family::Ddlm),
            (4, Family::Ssd),
        ] {
            let mut r = req(id, 10);
            r.family = Some(fam.into());
            let (tx, _rx) = chan();
            s.submit(r, tx).unwrap();
        }
        // the ssd worker only ever sees ssd requests, FIFO among them,
        // and skipping the ddlm head does not disturb ddlm's order
        assert_eq!(s.next_for(1).unwrap().req.id, 2);
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
        assert_eq!(s.next_for(1).unwrap().req.id, 4);
        assert_eq!(s.next_for(0).unwrap().req.id, 3);
        assert!(s.next_for(0).is_none());
        assert!(s.next_for(1).is_none());
    }

    #[test]
    fn family_defaults_to_fleet_default_at_admission() {
        let s = Scheduler::new(8, fleet(&[Family::Ssd]));
        let (tx, _rx) = chan();
        s.submit(req(1, 10), tx).unwrap(); // no family named
        let q = s.next_for(0).unwrap();
        assert_eq!(q.family, Family::Ssd);
    }

    #[test]
    fn unserved_family_rejected_with_invalid_request() {
        let s = Scheduler::new(8, fleet(&[Family::Ddlm]));
        let (tx, rx) = chan();
        let mut r = req(1, 10);
        r.family = Some(Family::Plaid.into());
        assert_eq!(s.submit(r, tx), Err(ServeError::InvalidRequest));
        assert!(rx.try_recv().is_err());
        assert_eq!(lock_or_recover(&s.metrics).rejected_invalid, 1);
        // even preflight-resolvable requests don't sneak through
        let (tx2, _rx2) = chan();
        let mut pre = req(2, 10);
        pre.family = Some(Family::Plaid.into());
        pre.policy = parse_policy("fixed:0").unwrap();
        assert_eq!(s.submit(pre, tx2), Err(ServeError::InvalidRequest));
    }

    #[test]
    fn last_family_worker_down_fails_only_that_familys_queue() {
        // two families; the ddlm shard dies with work queued for both
        let s = Scheduler::new(8, fleet(&[Family::Ddlm, Family::Ssd]));
        let (tx_d, rx_d) = chan();
        s.submit(req(1, 10), tx_d).unwrap(); // defaults to ddlm
        let (tx_s, rx_s) = chan();
        let mut rs = req(2, 10);
        rs.family = Some(Family::Ssd.into());
        s.submit(rs, tx_s).unwrap();
        s.worker_down(0);
        // the ddlm request failed over; the ssd one still waits
        assert_eq!(rx_d.recv().unwrap().unwrap_err(), ServeError::Unavailable);
        assert!(rx_s.try_recv().is_err());
        assert_eq!(s.queue_depth(), 1);
        // new ddlm submits reject as unserveable; ssd still admits
        let (tx3, _rx3) = chan();
        assert_eq!(s.submit(req(3, 10), tx3), Err(ServeError::InvalidRequest));
        let (tx4, _rx4) = chan();
        let mut r4 = req(4, 10);
        r4.family = Some(Family::Ssd.into());
        assert!(s.submit(r4, tx4).is_ok());
        assert_eq!(s.next_for(1).unwrap().req.id, 2);
    }

    #[test]
    fn cancel_queued_request_replies_and_counts() {
        let s = sched(8, 1);
        let (tx, rx) = chan();
        s.submit(req(11, 10), tx).unwrap();
        assert_eq!(s.cancel(11), CancelOutcome::Queued);
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::Cancelled);
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(lock_or_recover(&s.metrics).cancelled, 1);
        // a second cancel finds nothing
        assert_eq!(s.cancel(11), CancelOutcome::NotFound);
    }

    #[test]
    fn cancel_running_request_flags_owning_worker() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(21, 10), tx).unwrap();
        let q = s.next_for(0).unwrap();
        assert_eq!(q.req.id, 21);
        assert_eq!(s.cancel(21), CancelOutcome::Running);
        assert!(s.cancel_requested(21));
        // the worker aborts it and reports finish
        s.finish(21);
        assert!(!s.cancel_requested(21));
        assert_eq!(s.cancel(21), CancelOutcome::NotFound);
        assert_eq!(s.running_count(), 0);
    }

    #[test]
    fn queued_deadline_expiry_is_answered_at_pop() {
        let s = sched(8, 1);
        let (tx, rx) = chan();
        let mut r = req(31, 10);
        r.deadline_ms = Some(0.0); // expires immediately
        s.submit(r, tx).unwrap();
        assert_eq!(s.queue_depth(), 1);
        assert!(s.next_for(0).is_none());
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(lock_or_recover(&s.metrics).deadline_exceeded, 1);
    }

    #[test]
    fn reap_expired_answers_queued_deadlines_without_a_pop() {
        // a busy fleet never pops, but the per-step reap sweep must
        // still answer expired queued requests
        let s = sched(8, 1);
        let (tx, rx) = chan();
        let mut dead = req(41, 10);
        dead.deadline_ms = Some(0.0);
        s.submit(dead, tx).unwrap();
        let (tx2, rx2) = chan();
        s.submit(req(42, 10), tx2).unwrap();
        s.reap_expired();
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert_eq!(s.queue_depth(), 1); // the live request survived
        assert!(rx2.try_recv().is_err());
        assert_eq!(lock_or_recover(&s.metrics).deadline_exceeded, 1);
    }

    #[test]
    fn expired_request_does_not_shadow_runnable_ones() {
        let s = sched(8, 1);
        let (tx, rx) = chan();
        let mut dead = req(1, 10);
        dead.deadline_ms = Some(0.0);
        s.submit(dead, tx).unwrap();
        let (tx2, _rx2) = chan();
        s.submit(req(2, 10), tx2).unwrap();
        // one pop skips the expired head and lands on the live request
        assert_eq!(s.next_for(0).unwrap().req.id, 2);
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
    }

    #[test]
    fn shutdown_rejects_new_work_and_wakes_idle_workers() {
        let s = sched(8, 1);
        s.shutdown();
        let (tx, _rx) = chan();
        assert_eq!(s.submit(req(1, 10), tx), Err(ServeError::Overloaded));
        // preflight-resolvable policies don't sneak past shutdown either
        let (tx2, _rx2) = chan();
        let mut pre = req(2, 10);
        pre.policy = parse_policy("fixed:0").unwrap();
        assert_eq!(s.submit(pre, tx2), Err(ServeError::Overloaded));
        assert_eq!(s.wait_for_work(0), IdleWait::Exit);
    }

    #[test]
    fn shutdown_drains_queued_work_before_exit() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(1, 10), tx).unwrap();
        s.shutdown();
        // queued work still wins over exit, so shutdown drains
        assert_eq!(s.wait_for_work(0), IdleWait::Work);
        assert!(s.next_for(0).is_some());
        assert_eq!(s.wait_for_work(0), IdleWait::Exit);
    }

    #[test]
    fn idle_wait_ignores_other_families_work() {
        // ssd work queued; the ddlm worker's idle predicate must stay
        // false (no busy wake), and shutdown still exits it
        let s = Scheduler::new(8, fleet(&[Family::Ddlm, Family::Ssd]));
        let (tx, _rx) = chan();
        let mut r = req(1, 10);
        r.family = Some(Family::Ssd.into());
        s.submit(r, tx).unwrap();
        assert_eq!(s.wait_for_work(1), IdleWait::Work);
        s.shutdown();
        // worker 0 (ddlm) sees no ddlm work → exits instead of spinning
        assert_eq!(s.wait_for_work(0), IdleWait::Exit);
        // worker 1 still drains its family first
        assert_eq!(s.wait_for_work(1), IdleWait::Work);
        assert_eq!(s.next_for(1).unwrap().req.id, 1);
        assert_eq!(s.wait_for_work(1), IdleWait::Exit);
    }

    #[test]
    fn duplicate_inflight_id_rejected_until_finished() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(5, 10), tx).unwrap();
        // duplicate while queued
        let (tx2, _rx2) = chan();
        assert_eq!(s.submit(req(5, 10), tx2), Err(ServeError::DuplicateId));
        // still duplicate while running
        assert_eq!(s.next_for(0).unwrap().req.id, 5);
        let (tx3, _rx3) = chan();
        assert_eq!(s.submit(req(5, 10), tx3), Err(ServeError::DuplicateId));
        assert_eq!(lock_or_recover(&s.metrics).rejected_invalid, 2);
        // a finished id is reusable
        s.finish(5);
        let (tx4, _rx4) = chan();
        assert!(s.submit(req(5, 10), tx4).is_ok());
    }

    #[test]
    fn immediate_requests_do_not_bypass_duplicate_check() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(4, 10), tx).unwrap();
        // while id 4 is live, a zero-step resubmission must reject —
        // answering it would emit two completions for one id
        let (tx2, rx2) = chan();
        assert_eq!(s.submit(req(4, 0), tx2), Err(ServeError::DuplicateId));
        assert!(rx2.try_recv().is_err());
        let (tx3, rx3) = chan();
        let mut pre = req(4, 10);
        pre.policy = parse_policy("fixed:0").unwrap();
        assert_eq!(s.submit(pre, tx3), Err(ServeError::DuplicateId));
        assert!(rx3.try_recv().is_err());
    }

    #[test]
    fn cancelled_queued_id_is_reusable() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(6, 10), tx).unwrap();
        assert_eq!(s.cancel(6), CancelOutcome::Queued);
        let (tx2, _rx2) = chan();
        assert!(s.submit(req(6, 10), tx2).is_ok());
    }

    #[test]
    fn overlong_prefix_rejected_at_admission() {
        let s = sched(8, 1).with_max_prefix(4);
        let (tx, rx) = chan();
        let mut r = req(1, 10);
        r.prefix = vec![0; 5];
        assert_eq!(s.submit(r, tx), Err(ServeError::InvalidRequest));
        // synchronous typed rejection: no queue slot, no reply traffic
        assert!(rx.try_recv().is_err());
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(lock_or_recover(&s.metrics).rejected_invalid, 1);
        // exactly at the bound is serveable
        let (tx2, _rx2) = chan();
        let mut ok = req(2, 10);
        ok.prefix = vec![0; 4];
        assert!(s.submit(ok, tx2).is_ok());
    }

    #[test]
    fn zero_step_budget_answered_at_admission() {
        // steps:0 with a non-preflight policy must not occupy a slot or
        // execute a device step: it is answered as exhausted right here
        let s = sched(8, 1);
        let (tx, rx) = chan();
        s.submit(req(3, 0), tx).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.steps_executed, 0);
        assert_eq!(resp.steps_budget, 0);
        assert!(!resp.halted_early);
        assert_eq!(resp.halt_reason, None);
        assert_eq!(s.queue_depth(), 0);
        let m = lock_or_recover(&s.metrics);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.steps_executed, 0);
        assert_eq!(m.steps_saved, 0);
    }

    #[test]
    fn worker_down_purges_its_running_state() {
        // two workers; worker 0 dies (e.g. panic) while owning a
        // request — the id must become reusable and the fleet stays up
        let s = sched(8, 2);
        let (tx, _rx) = chan();
        s.submit(req(9, 10), tx).unwrap();
        assert_eq!(s.next_for(0).unwrap().req.id, 9);
        // flag a cancel too, so stale cancel state is exercised
        assert_eq!(s.cancel(9), CancelOutcome::Running);
        s.worker_down(0);
        assert_eq!(s.running_count(), 0);
        assert!(!s.cancel_requested(9));
        let (tx2, _rx2) = chan();
        assert!(s.submit(req(9, 10), tx2).is_ok());
        // the surviving worker still drains the queue
        assert_eq!(s.next_for(1).unwrap().req.id, 9);
    }

    #[test]
    fn last_worker_down_fails_queue_to_unavailable() {
        let s = sched(8, 1);
        let (tx, rx) = chan();
        s.submit(req(5, 10), tx).unwrap();
        s.worker_down(0);
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::Unavailable);
        assert_eq!(s.queue_depth(), 0);
        // with no workers left, new submits fail fast
        let (tx2, _rx2) = chan();
        assert_eq!(s.submit(req(6, 10), tx2), Err(ServeError::Unavailable));
    }

    #[test]
    fn halt_queued_request_finalizes_gracefully() {
        // halt (unlike cancel) answers a queued request with a NORMAL
        // zero-step completion carrying halt_reason:"client"
        let s = sched(8, 1);
        let (tx, rx) = chan();
        s.submit(req(11, 40), tx).unwrap();
        assert_eq!(s.halt(11), CancelOutcome::Queued);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 11);
        assert_eq!(resp.steps_executed, 0);
        assert_eq!(resp.steps_budget, 40);
        assert!(resp.halted_early);
        assert_eq!(resp.halt_reason.as_deref(), Some("client"));
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.family, Some(Family::Ddlm.into()));
        assert_eq!(s.queue_depth(), 0);
        let m = lock_or_recover(&s.metrics);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.steps_saved, 40);
        assert_eq!(m.halted_by.get("client"), Some(&1));
        drop(m);
        // a halted id is reusable and a second halt finds nothing
        assert_eq!(s.halt(11), CancelOutcome::NotFound);
        let (tx2, _rx2) = chan();
        assert!(s.submit(req(11, 10), tx2).is_ok());
    }

    #[test]
    fn halt_running_request_flags_owning_worker() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        s.submit(req(21, 10), tx).unwrap();
        assert_eq!(s.next_for(0).unwrap().req.id, 21);
        assert_eq!(s.halt(21), CancelOutcome::Running);
        assert!(s.halt_requested(21));
        assert_eq!(s.flagged(21), Some(Flagged::Halt));
        // halt and cancel flags are independent: an explicit cancel
        // outranks the graceful halt in the combined check
        assert!(!s.cancel_requested(21));
        assert_eq!(s.cancel(21), CancelOutcome::Running);
        assert_eq!(s.flagged(21), Some(Flagged::Cancel));
        s.finish(21);
        assert!(!s.halt_requested(21));
        assert_eq!(s.flagged(21), None);
        assert_eq!(s.halt(21), CancelOutcome::NotFound);
    }

    #[test]
    fn flagged_sweep_matches_per_id_checks_under_one_lock() {
        let s = sched(8, 1);
        for id in [41u64, 42, 43] {
            let (tx, _rx) = chan();
            s.submit(req(id, 10), tx).unwrap();
            assert_eq!(s.next_for(0).unwrap().req.id, id);
        }
        assert_eq!(s.cancel(41), CancelOutcome::Running);
        assert_eq!(s.halt(42), CancelOutcome::Running);
        // cancel outranks halt in the combined verdict
        assert_eq!(s.halt(41), CancelOutcome::Running);
        let verdicts = s.flagged_sweep(&[41, 42, 43, 99]);
        assert_eq!(
            verdicts,
            vec![
                Some(Flagged::Cancel),
                Some(Flagged::Halt),
                None,
                None // unknown ids are simply unflagged
            ]
        );
        // order follows the input ids, and agrees with flagged()
        for (&id, v) in [41u64, 42, 43, 99].iter().zip(&verdicts) {
            assert_eq!(s.flagged(id), *v);
        }
        assert!(s.flagged_sweep(&[]).is_empty());
        // the into-variant clears and refills caller scratch
        let mut scratch = vec![Some(Flagged::Halt); 7];
        s.flagged_sweep_into(&[43, 41], &mut scratch);
        assert_eq!(scratch, vec![None, Some(Flagged::Cancel)]);
        s.flagged_sweep_into(&[], &mut scratch);
        assert!(scratch.is_empty());
    }

    #[test]
    fn progress_subscriber_travels_with_the_queued_request() {
        let s = sched(8, 1);
        let (tx, _rx) = chan();
        let (ptx, prx) = super::super::progress::channel(8);
        let mut r = req(31, 100);
        r.progress_every = Some(10);
        s.submit_with_progress(r, tx, Some(ptx)).unwrap();
        let q = s.next_for(0).unwrap();
        assert_eq!(q.req.progress_every, Some(10));
        let ptx = q.progress.expect("progress subscriber lost at admission");
        ptx.send(ProgressEvent {
            id: 31,
            step: 10,
            steps_budget: 100,
            stats: Default::default(),
            tokens: None,
            predicted_steps_remaining: None,
            predicted_total_steps: None,
            frozen_mask: None,
        })
        .unwrap();
        let ev = prx.recv().unwrap();
        assert_eq!((ev.id, ev.step), (31, 10));
        // dropping the sender ends the subscriber's stream
        drop(ptx);
        assert!(prx.recv().is_err());
    }

    /// Estimator trained to ~100 steps at ~2ms/step for ddlm.
    fn trained_est() -> Arc<Estimator> {
        let est = Arc::new(Estimator::new());
        let fam: FamilyId = Family::Ddlm.into();
        for _ in 0..30 {
            est.observe_completion(fam, 100, &[]);
            est.observe_step_latency(fam, 2.0);
        }
        est
    }

    #[test]
    fn infeasible_deadline_rejected_when_admission_enabled() {
        let s = sched(8, 1).with_predictor(
            trained_est(),
            true,
            PackingMode::Fifo,
        );
        // ~100 steps × ~2ms = ~200ms predicted; a 50ms deadline can't
        // be met — typed rejection before any queue slot or device work
        let (tx, rx) = chan();
        let mut r = req(1, 600);
        r.deadline_ms = Some(50.0);
        assert_eq!(s.submit(r, tx), Err(ServeError::InfeasibleDeadline));
        assert!(rx.try_recv().is_err());
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(lock_or_recover(&s.metrics).rejected_infeasible, 1);
        // a roomy deadline admits, and carries its prediction along
        let (tx2, _rx2) = chan();
        let mut ok = req(2, 600);
        ok.deadline_ms = Some(5_000.0);
        assert!(s.submit(ok, tx2).is_ok());
        assert_eq!(s.next_for(0).unwrap().predicted_steps, Some(100));
        // no deadline = nothing to be infeasible against
        let (tx3, _rx3) = chan();
        assert!(s.submit(req(3, 600), tx3).is_ok());
    }

    #[test]
    fn deep_queue_rejects_a_deadline_the_idle_fleet_could_meet() {
        let s = sched(16, 1).with_predictor(
            trained_est(),
            true,
            PackingMode::Fifo,
        );
        // stack up backlog: 5 × 600-budget requests, each predicted at
        // ~100 steps → 500 queued steps ≈ 1000ms of queue wait
        for id in 1..=5 {
            let (tx, _rx) = chan();
            s.submit(req(id, 600), tx).unwrap();
        }
        assert_eq!(s.queued_steps_for(Family::Ddlm), 500);
        // own work is ~200ms — fine idle, hopeless behind the queue:
        // (100 own + 500 ahead) × 2ms ≈ 1200ms > 500ms deadline
        let (tx, rx) = chan();
        let mut r = req(6, 600);
        r.deadline_ms = Some(500.0);
        assert_eq!(s.submit(r, tx), Err(ServeError::InfeasibleDeadline));
        assert!(rx.try_recv().is_err());
        assert_eq!(lock_or_recover(&s.metrics).rejected_infeasible, 1);
        // draining the queue releases its priced backlog...
        while s.next_for(0).is_some() {}
        assert_eq!(s.queued_steps_for(Family::Ddlm), 0);
        // ...and the same deadline admits again
        let (tx2, _rx2) = chan();
        let mut ok = req(7, 600);
        ok.deadline_ms = Some(500.0);
        assert!(s.submit(ok, tx2).is_ok());
    }

    #[test]
    fn cold_start_estimator_admits_any_deadline() {
        // no latency observations → feasibility is Unknown → admit
        let s = sched(8, 1).with_predictor(
            Arc::new(Estimator::new()),
            true,
            PackingMode::Fifo,
        );
        let (tx, _rx) = chan();
        let mut r = req(1, 600);
        r.deadline_ms = Some(1.0);
        assert!(s.submit(r, tx).is_ok());
        // cold-start prediction = the budget
        assert_eq!(s.next_for(0).unwrap().predicted_steps, Some(600));
    }

    #[test]
    fn admission_gate_off_never_rejects_infeasible() {
        // predictor present (e.g. for SRPT) but the admission gate off:
        // even a hopeless deadline is admitted
        let s = sched(8, 1).with_predictor(
            trained_est(),
            false,
            PackingMode::Fifo,
        );
        let (tx, _rx) = chan();
        let mut r = req(1, 600);
        r.deadline_ms = Some(1.0);
        assert!(s.submit(r, tx).is_ok());
        assert_eq!(lock_or_recover(&s.metrics).rejected_infeasible, 0);
    }

    #[test]
    fn srpt_orders_same_class_by_predicted_steps() {
        // cold estimator: prediction = budget, so SRPT degrades to
        // shortest-budget-first within the class
        let s = sched(16, 1).with_predictor(
            Arc::new(Estimator::new()),
            false,
            PackingMode::Srpt,
        );
        for (id, steps) in [(1, 300), (2, 50), (3, 100)] {
            let (tx, _rx) = chan();
            s.submit(req(id, steps), tx).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| s.next_for(0))
            .map(|q| q.req.id)
            .collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn srpt_never_reorders_across_priority_classes() {
        let s = sched(16, 1).with_predictor(
            Arc::new(Estimator::new()),
            false,
            PackingMode::Srpt,
        );
        // a huge high-priority request still outranks a tiny normal one
        let mut big = req(1, 1000);
        big.priority = Priority::High;
        let (tx, _rx) = chan();
        s.submit(big, tx).unwrap();
        let (tx2, _rx2) = chan();
        s.submit(req(2, 10), tx2).unwrap();
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
        assert_eq!(s.next_for(0).unwrap().req.id, 2);
    }

    #[test]
    fn srpt_ties_keep_fifo_order() {
        let s = sched(16, 1).with_predictor(
            Arc::new(Estimator::new()),
            false,
            PackingMode::Srpt,
        );
        for id in [1u64, 2, 3] {
            let (tx, _rx) = chan();
            s.submit(req(id, 100), tx).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| s.next_for(0))
            .map(|q| q.req.id)
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn fifo_default_ignores_predictions_entirely() {
        // no predictor configured: submissions drain in FIFO order and
        // carry no prediction
        let s = sched(16, 1);
        for (id, steps) in [(1, 300), (2, 50), (3, 100)] {
            let (tx, _rx) = chan();
            s.submit(req(id, steps), tx).unwrap();
        }
        let popped: Vec<QueuedReq> =
            std::iter::from_fn(|| s.next_for(0)).collect();
        let order: Vec<u64> = popped.iter().map(|q| q.req.id).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert!(popped.iter().all(|q| q.predicted_steps.is_none()));
    }

    #[test]
    fn family_cap_rejects_full_family_without_blocking_others() {
        let s = Scheduler::new(16, fleet(&[Family::Ddlm, Family::Ssd]))
            .with_family_caps(vec![(Family::Ddlm.into(), 1)]);
        let (tx, _rx) = chan();
        s.submit(req(1, 10), tx).unwrap(); // ddlm slot taken
        // ddlm is at its cap: typed overload...
        let (tx2, rx2) = chan();
        assert_eq!(s.submit(req(2, 10), tx2), Err(ServeError::Overloaded));
        assert!(rx2.try_recv().is_err());
        assert_eq!(lock_or_recover(&s.metrics).rejected_overloaded, 1);
        // ...but ssd admission is untouched by ddlm's burst
        let (tx3, _rx3) = chan();
        let mut r3 = req(3, 10);
        r3.family = Some(Family::Ssd.into());
        assert!(s.submit(r3, tx3).is_ok());
        // draining the ddlm queue frees its family slot again
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
        let (tx4, _rx4) = chan();
        assert!(s.submit(req(4, 10), tx4).is_ok());
    }

    #[test]
    fn internal_error_carries_detail() {
        let e = ServeError::Internal("token_download_failed");
        assert_eq!(e.as_str(), "internal");
        assert_eq!(e.detail(), Some("token_download_failed"));
        assert_eq!(ServeError::Overloaded.detail(), None);
        assert_eq!(
            ServeError::InfeasibleDeadline.as_str(),
            "infeasible_deadline"
        );
    }

    // ---- elastic fleet: rebind, drain/requeue, migration routing ----

    fn order(batch: Option<usize>) -> RebindOrder {
        RebindOrder {
            family: None,
            batch,
            checkpoint: None,
            reply: None,
        }
    }

    /// Attach a synthetic mid-generation resume (half the budget done)
    /// to a popped request, as a draining worker would.
    fn resumed(mut q: QueuedReq, from: Option<usize>) -> QueuedReq {
        let export = crate::sampler::session::SlotExport::synthetic(
            q.family,
            q.req.n_steps,
            q.req.n_steps / 2,
        );
        q.resume = Some(Box::new(ResumeState {
            export,
            policy: Box::new(crate::halting::NoHalt),
            started: Instant::now(),
            prev_kl: None,
            tokens_frozen: 0,
            frozen_token_steps: 0,
            token_steps_saved: 0,
            bucket_entry: [None; N_BUCKETS],
            slope_entry: [None; N_SLOPE_BUCKETS],
            last_prediction: None,
            migrated_from: from,
        }));
        q
    }

    #[test]
    fn rebind_order_wakes_idle_worker_and_is_taken_once() {
        let s = sched(8, 1);
        assert!(!s.rebind_pending(0));
        s.request_rebind(0, order(Some(1))).unwrap();
        assert!(s.rebind_pending(0));
        // one order in flight at a time, typed refusal
        assert_eq!(s.request_rebind(0, order(None)), Err("rebind_in_flight"));
        // the idle wait surfaces the order without blocking
        assert_eq!(s.wait_for_work(0), IdleWait::Rebind);
        let o = s.take_rebind(0).unwrap();
        assert_eq!(o.batch, Some(1));
        assert!(s.take_rebind(0).is_none());
        assert!(!s.rebind_pending(0));
        // unknown and exited workers refuse typed
        assert_eq!(s.request_rebind(9, order(None)), Err("unknown_worker"));
        s.worker_down(0);
        assert_eq!(s.request_rebind(0, order(None)), Err("worker_down"));
    }

    #[test]
    fn worker_down_fails_its_pending_rebind_order() {
        let s = sched(8, 2);
        let (rtx, rrx) = mpsc::channel();
        s.request_rebind(
            0,
            RebindOrder {
                family: None,
                batch: Some(1),
                checkpoint: None,
                reply: Some(rtx),
            },
        )
        .unwrap();
        s.worker_down(0);
        // the requester is answered, not hung
        assert!(rrx.recv().unwrap().is_err());
    }

    #[test]
    fn requeue_drained_restores_front_order_and_tables() {
        let s = sched(8, 2);
        for id in 1..=3 {
            let (tx, _rx) = chan();
            s.submit(req(id, 10), tx).unwrap();
        }
        let a = s.next_for(0).unwrap();
        let b = s.next_for(0).unwrap();
        assert_eq!((a.req.id, b.req.id), (1, 2));
        assert_eq!(s.running_count(), 2);
        assert_eq!(s.queued_steps_for(Family::Ddlm), 10);
        s.requeue_drained(vec![a, b]);
        // back in the queue, ahead of the untouched tail, in their
        // original order — and fully accounted
        assert_eq!(s.running_count(), 0);
        assert_eq!(s.queue_depth(), 3);
        assert_eq!(s.queued_steps_for(Family::Ddlm), 30);
        // the ids stayed live across the drain: still duplicates
        let (txd, _rxd) = chan();
        assert_eq!(s.submit(req(1, 10), txd), Err(ServeError::DuplicateId));
        let drained: Vec<u64> = std::iter::from_fn(|| s.next_for(1))
            .map(|q| q.req.id)
            .collect();
        assert_eq!(drained, vec![1, 2, 3]);
    }

    #[test]
    fn resumed_requests_cost_their_remaining_steps() {
        let s = sched(8, 2);
        let (tx, _rx) = chan();
        s.submit(req(1, 100), tx).unwrap();
        let q = s.next_for(0).unwrap();
        // half done: the requeued cost is the remaining 50, not 100
        s.requeue_drained(vec![resumed(q, None)]);
        assert_eq!(s.queued_steps_for(Family::Ddlm), 50);
        let got = s.next_for(1).unwrap();
        assert_eq!(got.resume.as_ref().unwrap().export.steps_remaining(), 50);
        assert_eq!(s.queued_steps_for(Family::Ddlm), 0);
    }

    #[test]
    fn migrated_request_avoids_its_source_while_another_worker_lives() {
        let s = sched(8, 2);
        let (tx, _rx) = chan();
        s.submit(req(1, 100), tx).unwrap();
        let q = s.next_for(0).unwrap();
        s.requeue_drained(vec![resumed(q, Some(0))]);
        // the source worker skips its own migrated slot...
        assert!(s.next_for(0).is_none());
        // ...the sibling picks it up, resume intact
        let got = s.next_for(1).unwrap();
        assert_eq!(got.req.id, 1);
        assert!(got.resume.is_some());
        // with the sibling gone the source is last resort and takes it
        s.requeue_drained(vec![resumed(got, Some(0))]);
        s.worker_down(1);
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
    }

    #[test]
    fn complete_rebind_repoints_routing_and_fails_dead_family_queue() {
        let s = Scheduler::new(8, fleet(&[Family::Ddlm, Family::Ssd]));
        let (tx, rx) = chan();
        s.submit(req(1, 10), tx).unwrap(); // ddlm (default family)
        // the only ddlm shard rebinds to ssd: queued ddlm work fails
        // over typed, exactly like a worker exit
        s.complete_rebind(0, Family::Ssd.into(), 4);
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::Unavailable);
        let (tx2, _rx2) = chan();
        assert_eq!(s.submit(req(2, 10), tx2), Err(ServeError::InvalidRequest));
        // worker 0 now serves ssd work
        let (tx3, _rx3) = chan();
        let mut r3 = req(3, 10);
        r3.family = Some(Family::Ssd.into());
        s.submit(r3, tx3).unwrap();
        assert_eq!(s.next_for(0).unwrap().req.id, 3);
        // same-family rebind (reshape / checkpoint swap) moves nothing
        s.complete_rebind(0, Family::Ssd.into(), 1);
        let snap = s.fleet_snapshot();
        assert_eq!(snap.workers[0].family, FamilyId::from(Family::Ssd));
        assert_eq!(snap.workers[0].batch, 1);
    }

    #[test]
    fn smaller_shard_detection_tracks_batches_and_liveness() {
        let s = sched(8, 3);
        s.register_worker_batch(0, 8);
        s.register_worker_batch(1, 1);
        s.register_worker_batch(2, 8);
        let fam: FamilyId = Family::Ddlm.into();
        // both b8 shards see the b1 shard; the b1 shard sees nothing
        // (workers 0 and 2 are equal-batch peers — peers don't count)
        assert!(s.smaller_shard_live(0, fam));
        assert!(s.smaller_shard_live(2, fam));
        assert!(!s.smaller_shard_live(1, fam));
        // a shard mid-rebind doesn't count as a destination
        s.request_rebind(1, order(Some(8))).unwrap();
        assert!(!s.smaller_shard_live(0, fam));
        let _ = s.take_rebind(1);
        assert!(s.smaller_shard_live(0, fam));
        // a dead shard doesn't count either
        s.worker_down(1);
        assert!(!s.smaller_shard_live(0, fam));
    }

    #[test]
    fn fleet_snapshot_reports_bindings_load_and_backlog() {
        let s = Scheduler::new(8, fleet(&[Family::Ddlm, Family::Ssd]));
        s.register_worker_batch(0, 8);
        s.register_worker_batch(1, 4);
        let (tx, _rx) = chan();
        s.submit(req(1, 10), tx).unwrap();
        let (tx2, _rx2) = chan();
        s.submit(req(2, 10), tx2).unwrap();
        assert_eq!(s.next_for(0).unwrap().req.id, 1);
        let snap = s.fleet_snapshot();
        assert_eq!(snap.workers.len(), 2);
        assert_eq!(snap.workers[0].batch, 8);
        assert_eq!(snap.workers[0].running, 1);
        assert!(snap.workers[0].alive);
        assert_eq!(snap.workers[1].running, 0);
        // one ddlm request still queued
        let fam: FamilyId = Family::Ddlm.into();
        assert_eq!(snap.queued_by_family[fam.index()], 1);
    }

    #[test]
    fn resort_reprices_the_queue_against_fresh_predictions() {
        // cold estimator at admission: predictions = budgets, so SRPT
        // packs [2 (50), 3 (100), 1 (300)]
        let est = Arc::new(Estimator::new());
        let s = sched(16, 1).with_predictor(
            est.clone(),
            false,
            PackingMode::Srpt,
        );
        for (id, steps) in [(1u64, 300), (2, 50), (3, 100)] {
            let (tx, _rx) = chan();
            s.submit(req(id, steps), tx).unwrap();
        }
        assert_eq!(s.queued_steps_for(Family::Ddlm), 450);
        // mid-burst the estimator learns generations halt at ~60 steps:
        // capped per budget the fresh predictions are 60 / 50 / 60 —
        // id 1's stale 300 collapses, and the 60-60 tie between 1 and 3
        // must keep FIFO order (1 before 3)
        let fam: FamilyId = Family::Ddlm.into();
        for _ in 0..30 {
            est.observe_completion(fam, 60, &[]);
        }
        s.resort_queues();
        assert_eq!(s.queued_steps_for(Family::Ddlm), 170);
        let drained: Vec<u64> = std::iter::from_fn(|| s.next_for(0))
            .map(|q| q.req.id)
            .collect();
        assert_eq!(drained, vec![2, 1, 3]);
    }

    #[test]
    fn note_estimator_update_throttles_the_resort() {
        let est = Arc::new(Estimator::new());
        let s = sched(16, 1).with_predictor(
            est.clone(),
            false,
            PackingMode::Srpt,
        );
        for (id, steps) in [(1u64, 300), (2, 50)] {
            let (tx, _rx) = chan();
            s.submit(req(id, steps), tx).unwrap();
        }
        let fam: FamilyId = Family::Ddlm.into();
        for _ in 0..30 {
            est.observe_completion(fam, 60, &[]);
        }
        // fewer than RESORT_PERIOD ticks: no re-sort yet
        for _ in 0..(RESORT_PERIOD - 1) {
            s.note_estimator_update();
        }
        assert_eq!(s.queued_steps_for(Family::Ddlm), 350);
        // the period-th tick re-prices (60 capped + 50)
        s.note_estimator_update();
        assert_eq!(s.queued_steps_for(Family::Ddlm), 110);
    }

    #[test]
    fn resort_without_predictor_or_under_fifo_is_inert() {
        // no predictor: both entry points are no-ops
        let s = sched(16, 1);
        let (tx, _rx) = chan();
        s.submit(req(1, 300), tx).unwrap();
        s.note_estimator_update();
        s.resort_queues();
        assert_eq!(s.queued_steps_for(Family::Ddlm), 300);
        // FIFO packing: re-pricing happens, order never changes
        let est = trained_est(); // learned ~100 steps
        let s2 = sched(16, 1).with_predictor(est, false, PackingMode::Fifo);
        for (id, steps) in [(1u64, 300), (2, 50)] {
            let (tx, _rx) = chan();
            s2.submit(req(id, steps), tx).unwrap();
        }
        s2.resort_queues();
        // prices refreshed (100 capped at budgets: 100 + 50)...
        assert_eq!(s2.queued_steps_for(Family::Ddlm), 150);
        // ...but FIFO order is untouched
        assert_eq!(s2.next_for(0).unwrap().req.id, 1);
        assert_eq!(s2.next_for(0).unwrap().req.id, 2);
    }
}
