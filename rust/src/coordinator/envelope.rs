//! Versioned wire envelope (v1): typed frames over a multiplexed
//! JSON-lines connection.
//!
//! Every v1 frame is one JSON object per line carrying `"v":1` and a
//! `"type"` tag; a line *without* a `v` key is a legacy one-shot
//! request/control and is served by the pre-envelope path unchanged
//! (autodetect is per line, so one connection may mix both).
//!
//! Client → server ([`Command`]):
//!
//! | frame | shape |
//! |---|---|
//! | submit  | `{"v":1,"type":"submit", ...GenRequest fields...}` — may set `progress_every:K` |
//! | cancel  | `{"v":1,"type":"cancel","id":N}` — abort, answers the submitter with `error:"cancelled"` |
//! | halt    | `{"v":1,"type":"halt","id":N}` — *graceful* finalize: the submitter receives a normal `done` with the current x0 decode and `halt_reason:"client"` |
//! | metrics | `{"v":1,"type":"metrics"}` |
//! | rebind  | `{"v":1,"type":"rebind","worker":W[,"family":F][,"batch":B][,"checkpoint":PATH]}` — admin: drain worker `W`'s in-flight slots back to the queue (resumable, zero dropped), rebuild its session under the new binding and rejoin.  Omitted fields keep the current value; an empty `checkpoint` string drops to init params |
//!
//! Server → client ([`Event`]):
//!
//! | frame | shape |
//! |---|---|
//! | progress | `{"v":1,"type":"progress","id":N,"step":S,"steps_budget":B,"entropy":..,"kl":..,"switches":..,"norm_x":..,"norm_x0":..[,"tokens":[..]][,"predicted_steps_remaining":R,"predicted_total_steps":T][,"frozen_mask":[0,1,..]]}` — `tokens` is the current decode (prefix positions forced), attached by workers; the `predicted_*` pair is the fleet predictor's live steps-to-halt estimate, present only when the engine runs with prediction enabled; `frozen_mask` (0/1 per position) is the token-level freeze state, present only when the submit set `frozen_mask:true` |
//! | done     | `{"v":1,"type":"done", ...GenResponse fields...}` — gains the same optional `predicted_*` pair under prediction |
//! | error    | `{"v":1,"type":"error","error":CODE[,"id":N][,"message":TEXT][,"retry_after_ms":MS]}` — `retry_after_ms` is a backoff hint attached to `overloaded`/`unavailable` answers while the fleet is degraded or browned out; absent from a healthy fleet, so pre-brownout error frames are byte-identical |
//! | cancel   | ack: `{"v":1,"type":"cancel","id":N,"cancelled":BOOL,"state":"queued"\|"running"\|"not_found"}` |
//! | halt     | ack: `{"v":1,"type":"halt","id":N,"found":BOOL,"state":...}` |
//! | rebind   | ack: `{"v":1,"type":"rebind","worker":W,"ok":BOOL[,"message":TEXT][,"family":F,"batch":B,"drained":D,"rebind_ms":MS]}` — `ok:false` means typed refusal or failure-and-revert |
//! | metrics  | `{"v":1,"type":"metrics","data":{...snapshot...}}` |
//!
//! Error codes: the scheduler's typed serving errors (`overloaded`,
//! `cancelled`, `deadline_exceeded`, `infeasible_deadline`,
//! `unavailable`, `invalid_request`, `duplicate_id`) plus
//! `unsupported_version` (a `v` the server does not speak) and
//! `internal` (carrying a `message` detail such as
//! `"token_download_failed"`).  Malformed frames map to
//! `invalid_request` with a human-readable `message`.
//!
//! Frames of different requests interleave freely on one connection
//! (that is the multiplexing); *within* one request, every `progress`
//! event precedes its terminal `done`/`error` frame.

use anyhow::{anyhow, Result};

use super::request::{GenRequest, GenResponse, ProgressEvent};
use crate::halting::StepStats;
use crate::util::json::Json;

/// The one protocol version this build speaks.
pub const PROTOCOL_VERSION: u64 = 1;

/// True when a parsed line is a versioned envelope frame; false means
/// the legacy bare-object protocol.
pub fn is_envelope(j: &Json) -> bool {
    j.get("v").is_some()
}

/// Typed failure turning a line into a [`Command`]; [`Self::code`] is
/// the wire error code, `Display` the human-readable message.
#[derive(Debug)]
pub enum FrameError {
    UnsupportedVersion(String),
    MissingType,
    UnknownType(String),
    MissingId(&'static str),
    BadSubmit(String),
}

impl FrameError {
    pub fn code(&self) -> &'static str {
        match self {
            FrameError::UnsupportedVersion(_) => "unsupported_version",
            _ => "invalid_request",
        }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::UnsupportedVersion(v) => write!(
                f,
                "unsupported protocol version {v} (this server speaks \
                 v{PROTOCOL_VERSION})"
            ),
            FrameError::MissingType => f.write_str("missing frame type"),
            FrameError::UnknownType(t) => write!(f, "unknown frame type {t:?}"),
            FrameError::MissingId(t) => {
                write!(f, "{t} frame needs an integer id")
            }
            FrameError::BadSubmit(m) => write!(f, "bad submit: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// A client-side frame (what the server parses off the wire).
pub enum Command {
    Submit(Box<GenRequest>),
    Cancel { id: u64 },
    Halt { id: u64 },
    Metrics,
    /// Admin: live-rebind one worker shard (drain → rebind → rejoin).
    /// `None` fields keep the worker's current value; an empty
    /// `checkpoint` string drops it back to init params.
    Rebind {
        worker: usize,
        family: Option<String>,
        batch: Option<usize>,
        checkpoint: Option<String>,
    },
}

impl Command {
    pub fn from_json(j: &Json) -> Result<Command, FrameError> {
        match j.get("v").and_then(Json::as_u64) {
            Some(PROTOCOL_VERSION) => {}
            _ => {
                return Err(FrameError::UnsupportedVersion(
                    j.get("v").map_or("?".to_string(), |v| v.encode()),
                ))
            }
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .ok_or(FrameError::MissingType)?;
        let need_id = |t| {
            j.get("id").and_then(Json::as_u64).ok_or(FrameError::MissingId(t))
        };
        match ty {
            "submit" => GenRequest::from_json(j)
                .map(|r| Command::Submit(Box::new(r)))
                .map_err(|e| FrameError::BadSubmit(format!("{e:#}"))),
            "cancel" => Ok(Command::Cancel { id: need_id("cancel")? }),
            "halt" => Ok(Command::Halt { id: need_id("halt")? }),
            "metrics" => Ok(Command::Metrics),
            "rebind" => Ok(Command::Rebind {
                worker: j
                    .get("worker")
                    .and_then(Json::as_usize)
                    .ok_or(FrameError::MissingId("rebind"))?,
                family: j
                    .get("family")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                batch: j.get("batch").and_then(Json::as_usize),
                checkpoint: j
                    .get("checkpoint")
                    .and_then(Json::as_str)
                    .map(str::to_string),
            }),
            other => Err(FrameError::UnknownType(other.to_string())),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = match self {
            Command::Submit(req) => {
                let m = req.to_json().into_obj();
                m
            }
            Command::Cancel { id } | Command::Halt { id } => {
                let m = Json::obj(vec![("id", Json::uint(*id))]).into_obj();
                m
            }
            Command::Metrics => Default::default(),
            Command::Rebind {
                worker,
                family,
                batch,
                checkpoint,
            } => {
                let mut fields =
                    vec![("worker", Json::uint(*worker as u64))];
                if let Some(f) = family {
                    fields.push(("family", Json::str(f.clone())));
                }
                if let Some(b) = batch {
                    fields.push(("batch", Json::uint(*b as u64)));
                }
                if let Some(c) = checkpoint {
                    fields.push(("checkpoint", Json::str(c.clone())));
                }
                let m = Json::obj(fields).into_obj();
                m
            }
        };
        let ty = match self {
            Command::Submit(_) => "submit",
            Command::Cancel { .. } => "cancel",
            Command::Halt { .. } => "halt",
            Command::Metrics => "metrics",
            Command::Rebind { .. } => "rebind",
        };
        m.insert("v".to_string(), Json::uint(PROTOCOL_VERSION));
        m.insert("type".to_string(), Json::str(ty));
        Json::Obj(m)
    }
}

/// A server-side frame (what a v1 client parses off the wire).
#[derive(Debug)]
pub enum Event {
    Progress(ProgressEvent),
    Done(GenResponse),
    Error {
        /// absent when the failing line carried no parseable id
        id: Option<u64>,
        code: String,
        message: Option<String>,
        /// backoff hint in milliseconds, attached to `overloaded` /
        /// `unavailable` answers while the fleet is degraded or
        /// browned out; absent (no wire bytes) from a healthy fleet
        retry_after_ms: Option<u64>,
    },
    CancelAck {
        id: u64,
        cancelled: bool,
        state: String,
    },
    HaltAck {
        id: u64,
        found: bool,
        state: String,
    },
    /// Rebind outcome: on success carries the worker's new binding plus
    /// the drain size and rebind latency; on refusal/failure `ok` is
    /// false and `message` names the reason (the worker kept — or
    /// reverted to — its previous binding).
    RebindAck {
        worker: usize,
        ok: bool,
        message: Option<String>,
        family: Option<String>,
        batch: Option<usize>,
        drained: Option<usize>,
        rebind_ms: Option<f64>,
    },
    Metrics(Json),
}

impl Event {
    pub fn to_json(&self) -> Json {
        let (ty, mut m) = match self {
            Event::Progress(p) => {
                let mut fields = vec![
                    ("id", Json::uint(p.id)),
                    ("step", Json::uint(p.step as u64)),
                    ("steps_budget", Json::uint(p.steps_budget as u64)),
                    ("entropy", Json::num(p.stats.entropy as f64)),
                    ("kl", Json::num(p.stats.kl as f64)),
                    ("switches", Json::num(p.stats.switches as f64)),
                    ("norm_x", Json::num(p.stats.norm_x as f64)),
                    ("norm_x0", Json::num(p.stats.norm_x0 as f64)),
                ];
                if let Some(toks) = &p.tokens {
                    fields.push((
                        "tokens",
                        Json::Arr(
                            toks.iter()
                                .map(|&t| Json::int(t as i64))
                                .collect(),
                        ),
                    ));
                }
                if let Some(r) = p.predicted_steps_remaining {
                    fields.push((
                        "predicted_steps_remaining",
                        Json::uint(r as u64),
                    ));
                }
                if let Some(t) = p.predicted_total_steps {
                    fields
                        .push(("predicted_total_steps", Json::uint(t as u64)));
                }
                if let Some(mask) = &p.frozen_mask {
                    fields.push((
                        "frozen_mask",
                        Json::Arr(
                            mask.iter()
                                .map(|&f| Json::uint(u64::from(f)))
                                .collect(),
                        ),
                    ));
                }
                let m = Json::obj(fields).into_obj();
                ("progress", m)
            }
            Event::Done(resp) => {
                let m = resp.to_json().into_obj();
                ("done", m)
            }
            Event::Error { id, code, message, retry_after_ms } => {
                let mut fields = vec![("error", Json::str(code.clone()))];
                if let Some(id) = id {
                    fields.push(("id", Json::uint(*id)));
                }
                if let Some(msg) = message {
                    fields.push(("message", Json::str(msg.clone())));
                }
                if let Some(ms) = retry_after_ms {
                    fields.push(("retry_after_ms", Json::uint(*ms)));
                }
                let m = Json::obj(fields).into_obj();
                ("error", m)
            }
            Event::CancelAck { id, cancelled, state } => {
                let m = Json::obj(vec![
                    ("id", Json::uint(*id)),
                    ("cancelled", Json::Bool(*cancelled)),
                    ("state", Json::str(state.clone())),
                ]).into_obj();
                ("cancel", m)
            }
            Event::HaltAck { id, found, state } => {
                let m = Json::obj(vec![
                    ("id", Json::uint(*id)),
                    ("found", Json::Bool(*found)),
                    ("state", Json::str(state.clone())),
                ]).into_obj();
                ("halt", m)
            }
            Event::RebindAck {
                worker,
                ok,
                message,
                family,
                batch,
                drained,
                rebind_ms,
            } => {
                let mut fields = vec![
                    ("worker", Json::uint(*worker as u64)),
                    ("ok", Json::Bool(*ok)),
                ];
                if let Some(msg) = message {
                    fields.push(("message", Json::str(msg.clone())));
                }
                if let Some(f) = family {
                    fields.push(("family", Json::str(f.clone())));
                }
                if let Some(b) = batch {
                    fields.push(("batch", Json::uint(*b as u64)));
                }
                if let Some(d) = drained {
                    fields.push(("drained", Json::uint(*d as u64)));
                }
                if let Some(ms) = rebind_ms {
                    fields.push(("rebind_ms", Json::num(*ms)));
                }
                let m = Json::obj(fields).into_obj();
                ("rebind", m)
            }
            Event::Metrics(data) => {
                let m = Json::obj(vec![("data", data.clone())]).into_obj();
                ("metrics", m)
            }
        };
        m.insert("v".to_string(), Json::uint(PROTOCOL_VERSION));
        m.insert("type".to_string(), Json::str(ty));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Event> {
        match j.get("v").and_then(Json::as_u64) {
            Some(PROTOCOL_VERSION) => {}
            other => {
                return Err(anyhow!("unsupported event version {other:?}"))
            }
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event without a type"))?;
        let need_id = || {
            j.get("id")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("{ty} event without an integer id"))
        };
        let need_str = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{ty} event missing {k}"))
        };
        let stat = |k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as f32
        };
        Ok(match ty {
            "progress" => {
                // mid-generation decode is optional (older servers
                // don't attach one); a present-but-malformed entry is
                // a hard error, mirroring the done frame's strictness
                let tokens = match j.get("tokens") {
                    None => None,
                    Some(arr) => {
                        let arr = arr.as_arr().ok_or_else(|| {
                            anyhow!("progress tokens must be an array")
                        })?;
                        let mut out = Vec::with_capacity(arr.len());
                        for (i, x) in arr.iter().enumerate() {
                            out.push(
                                x.as_i64()
                                    .and_then(|t| i32::try_from(t).ok())
                                    .ok_or_else(|| {
                                        anyhow!(
                                            "progress tokens[{i}] is not \
                                             an integer token"
                                        )
                                    })?,
                            );
                        }
                        Some(out)
                    }
                };
                Event::Progress(ProgressEvent {
                    id: need_id()?,
                    step: j
                        .get("step")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| {
                            anyhow!("progress event missing step")
                        })?,
                    steps_budget: j
                        .get("steps_budget")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    stats: StepStats {
                        entropy: stat("entropy"),
                        kl: stat("kl"),
                        switches: stat("switches"),
                        norm_x: stat("norm_x"),
                        norm_x0: stat("norm_x0"),
                    },
                    tokens,
                    predicted_steps_remaining: j
                        .get("predicted_steps_remaining")
                        .and_then(Json::as_usize),
                    predicted_total_steps: j
                        .get("predicted_total_steps")
                        .and_then(Json::as_usize),
                    // optional (requests opt in); present-but-malformed
                    // entries are hard errors like the decode above
                    frozen_mask: match j.get("frozen_mask") {
                        None => None,
                        Some(arr) => {
                            let arr = arr.as_arr().ok_or_else(|| {
                                anyhow!("progress frozen_mask must be an array")
                            })?;
                            let mut out = Vec::with_capacity(arr.len());
                            for (i, x) in arr.iter().enumerate() {
                                match x.as_u64() {
                                    Some(0) => out.push(false),
                                    Some(1) => out.push(true),
                                    _ => anyhow::bail!(
                                        "progress frozen_mask[{i}] is not 0/1"
                                    ),
                                }
                            }
                            Some(out)
                        }
                    },
                })
            }
            "done" => Event::Done(GenResponse::from_json(j)?),
            "error" => Event::Error {
                id: j.get("id").and_then(Json::as_u64),
                code: need_str("error")?,
                message: j
                    .get("message")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                retry_after_ms: j
                    .get("retry_after_ms")
                    .and_then(Json::as_u64),
            },
            "cancel" => Event::CancelAck {
                id: need_id()?,
                cancelled: j
                    .get("cancelled")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                state: need_str("state")?,
            },
            "halt" => Event::HaltAck {
                id: need_id()?,
                found: j
                    .get("found")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                state: need_str("state")?,
            },
            "rebind" => Event::RebindAck {
                worker: j
                    .get("worker")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| {
                        anyhow!("rebind event without a worker index")
                    })?,
                ok: j.get("ok").and_then(Json::as_bool).unwrap_or(false),
                message: j
                    .get("message")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                family: j
                    .get("family")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                batch: j.get("batch").and_then(Json::as_usize),
                drained: j.get("drained").and_then(Json::as_usize),
                rebind_ms: j.get("rebind_ms").and_then(Json::as_f64),
            },
            "metrics" => Event::Metrics(
                j.get("data").cloned().unwrap_or(Json::Null),
            ),
            other => anyhow::bail!("unknown event type {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halting::parse_policy;

    #[test]
    fn legacy_lines_are_not_envelopes() {
        let legacy =
            Json::parse(r#"{"id":1,"steps":10,"criterion":"none"}"#).unwrap();
        assert!(!is_envelope(&legacy));
        let v1 = Json::parse(r#"{"v":1,"type":"metrics"}"#).unwrap();
        assert!(is_envelope(&v1));
    }

    #[test]
    fn command_roundtrip_all_variants() {
        let mut req = GenRequest::new(u64::MAX, 200);
        req.policy = parse_policy("any(entropy:0.25,patience:20:0)").unwrap();
        req.progress_every = Some(50);
        for cmd in [
            Command::Submit(Box::new(req)),
            Command::Cancel { id: 7 },
            Command::Halt { id: (1 << 53) + 1 },
            Command::Metrics,
            Command::Rebind {
                worker: 2,
                family: Some("ssd".to_string()),
                batch: Some(1),
                checkpoint: Some(String::new()),
            },
            Command::Rebind {
                worker: 0,
                family: None,
                batch: None,
                checkpoint: None,
            },
        ] {
            let j = cmd.to_json();
            assert_eq!(j.get("v").and_then(Json::as_u64), Some(1));
            let encoded = j.encode();
            let back =
                Command::from_json(&Json::parse(&encoded).unwrap()).unwrap();
            match (&cmd, &back) {
                (Command::Submit(a), Command::Submit(b)) => {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.progress_every, b.progress_every);
                    assert_eq!(a.policy.to_spec(), b.policy.to_spec());
                }
                (Command::Cancel { id: a }, Command::Cancel { id: b })
                | (Command::Halt { id: a }, Command::Halt { id: b }) => {
                    assert_eq!(a, b)
                }
                (Command::Metrics, Command::Metrics) => {}
                (
                    Command::Rebind {
                        worker: wa,
                        family: fa,
                        batch: ba,
                        checkpoint: ca,
                    },
                    Command::Rebind {
                        worker: wb,
                        family: fb,
                        batch: bb,
                        checkpoint: cb,
                    },
                ) => assert_eq!((wa, fa, ba, ca), (wb, fb, bb, cb)),
                _ => panic!("variant changed over the wire: {encoded}"),
            }
        }
    }

    #[test]
    fn commands_reject_bad_versions_and_types() {
        let e = Command::from_json(
            &Json::parse(r#"{"v":2,"type":"submit","id":1,"steps":5}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert_eq!(e.code(), "unsupported_version");
        let e = Command::from_json(
            &Json::parse(r#"{"v":1,"type":"selfdestruct"}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(e.code(), "invalid_request");
        let e = Command::from_json(
            &Json::parse(r#"{"v":1,"type":"halt"}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(e.code(), "invalid_request");
        assert!(e.to_string().contains("halt"));
        // a submit with a malformed prefix is a typed bad-submit
        let e = Command::from_json(
            &Json::parse(
                r#"{"v":1,"type":"submit","id":1,"steps":5,"prefix":["x"]}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(e.code(), "invalid_request");
    }

    #[test]
    fn event_roundtrip_all_variants() {
        let events = vec![
            Event::Progress(ProgressEvent {
                id: u64::MAX,
                step: 50,
                steps_budget: 200,
                stats: StepStats {
                    entropy: 0.5,
                    kl: 0.25,
                    switches: 3.0,
                    norm_x: 8.0,
                    norm_x0: 7.5,
                },
                tokens: Some(vec![3, 0, -1]),
                predicted_steps_remaining: Some(30),
                predicted_total_steps: Some(80),
                frozen_mask: Some(vec![true, false, true]),
            }),
            // older servers attach no decode and no prediction: the
            // fields are optional
            Event::Progress(ProgressEvent {
                id: 2,
                step: 10,
                steps_budget: 100,
                stats: StepStats::default(),
                tokens: None,
                predicted_steps_remaining: None,
                predicted_total_steps: None,
                frozen_mask: None,
            }),
            Event::Error {
                id: Some(4),
                code: "overloaded".to_string(),
                message: None,
                retry_after_ms: None,
            },
            Event::Error {
                id: None,
                code: "invalid_request".to_string(),
                message: Some("bad criterion".to_string()),
                retry_after_ms: None,
            },
            // a degraded fleet attaches the backoff hint
            Event::Error {
                id: Some(11),
                code: "unavailable".to_string(),
                message: None,
                retry_after_ms: Some(2000),
            },
            Event::CancelAck {
                id: 9,
                cancelled: true,
                state: "queued".to_string(),
            },
            Event::HaltAck {
                id: 9,
                found: true,
                state: "running".to_string(),
            },
            Event::RebindAck {
                worker: 1,
                ok: true,
                message: None,
                family: Some("ddlm".to_string()),
                batch: Some(8),
                drained: Some(3),
                rebind_ms: Some(12.5),
            },
            Event::RebindAck {
                worker: 4,
                ok: false,
                message: Some("rebind_in_flight".to_string()),
                family: None,
                batch: None,
                drained: None,
                rebind_ms: None,
            },
            Event::Metrics(Json::obj(vec![(
                "requests_completed",
                Json::uint(3),
            )])),
        ];
        for ev in events {
            let encoded = ev.to_json().encode();
            let back =
                Event::from_json(&Json::parse(&encoded).unwrap()).unwrap();
            match (&ev, &back) {
                (Event::Progress(a), Event::Progress(b)) => {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.step, b.step);
                    assert_eq!(a.steps_budget, b.steps_budget);
                    assert!((a.stats.entropy - b.stats.entropy).abs() < 1e-6);
                    assert!((a.stats.kl - b.stats.kl).abs() < 1e-9);
                    assert_eq!(a.tokens, b.tokens);
                    assert_eq!(
                        a.predicted_steps_remaining,
                        b.predicted_steps_remaining
                    );
                    assert_eq!(
                        a.predicted_total_steps,
                        b.predicted_total_steps
                    );
                    assert_eq!(a.frozen_mask, b.frozen_mask);
                }
                (
                    Event::Error {
                        id: a,
                        code: ca,
                        message: ma,
                        retry_after_ms: ra,
                    },
                    Event::Error {
                        id: b,
                        code: cb,
                        message: mb,
                        retry_after_ms: rb,
                    },
                ) => {
                    assert_eq!((a, ca, ma, ra), (b, cb, mb, rb));
                }
                (
                    Event::CancelAck { id: a, cancelled: xa, state: sa },
                    Event::CancelAck { id: b, cancelled: xb, state: sb },
                ) => assert_eq!((a, xa, sa), (b, xb, sb)),
                (
                    Event::HaltAck { id: a, found: xa, state: sa },
                    Event::HaltAck { id: b, found: xb, state: sb },
                ) => assert_eq!((a, xa, sa), (b, xb, sb)),
                (Event::Metrics(a), Event::Metrics(b)) => assert_eq!(a, b),
                (
                    Event::RebindAck {
                        worker: wa,
                        ok: oa,
                        message: ma,
                        family: fa,
                        batch: ba,
                        drained: da,
                        rebind_ms: ra,
                    },
                    Event::RebindAck {
                        worker: wb,
                        ok: ob,
                        message: mb,
                        family: fb,
                        batch: bb,
                        drained: db,
                        rebind_ms: rb,
                    },
                ) => {
                    assert_eq!((wa, oa, ma, fa, ba, da), (wb, ob, mb, fb, bb, db));
                    assert_eq!(ra.is_some(), rb.is_some());
                    if let (Some(x), Some(y)) = (ra, rb) {
                        assert!((x - y).abs() < 1e-9);
                    }
                }
                _ => panic!("variant changed over the wire: {encoded}"),
            }
        }
    }

    #[test]
    fn done_event_roundtrips_response() {
        let resp = GenResponse {
            id: (1 << 60) + 3,
            tokens: vec![5, 6, 7],
            steps_executed: 120,
            steps_budget: 200,
            halted_early: true,
            halt_reason: Some("client".to_string()),
            latency_ms: 45.5,
            queue_ms: 1.25,
            family: None,
            predicted_steps_remaining: Some(2),
            predicted_total_steps: Some(118),
            final_stats: StepStats::default(),
        };
        let encoded = Event::Done(resp).to_json().encode();
        let Event::Done(back) =
            Event::from_json(&Json::parse(&encoded).unwrap()).unwrap()
        else {
            panic!("not a done frame: {encoded}")
        };
        assert_eq!(back.id, (1 << 60) + 3);
        assert_eq!(back.halt_reason.as_deref(), Some("client"));
        assert_eq!(back.tokens, vec![5, 6, 7]);
        assert_eq!(back.predicted_steps_remaining, Some(2));
        assert_eq!(back.predicted_total_steps, Some(118));
    }

    #[test]
    fn progress_without_prediction_omits_fields_on_wire() {
        let encoded = Event::Progress(ProgressEvent {
            id: 1,
            step: 5,
            steps_budget: 50,
            stats: StepStats::default(),
            tokens: None,
            predicted_steps_remaining: None,
            predicted_total_steps: None,
            frozen_mask: None,
        })
        .to_json()
        .encode();
        assert!(!encoded.contains("predicted"), "{encoded}");
        // token halting off (or not requested) leaves the frame
        // byte-free of the optional freeze field too
        assert!(!encoded.contains("frozen"), "{encoded}");
    }

    /// A healthy fleet's error frames carry no backoff hint — the
    /// pre-brownout wire bytes are pinned exactly.
    #[test]
    fn healthy_error_frame_bytes_are_unchanged() {
        let encoded = Event::Error {
            id: Some(4),
            code: "overloaded".to_string(),
            message: None,
            retry_after_ms: None,
        }
        .to_json()
        .encode();
        assert_eq!(
            encoded,
            r#"{"error":"overloaded","id":4,"type":"error","v":1}"#
        );
    }
}
