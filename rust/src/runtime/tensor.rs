//! Host tensor type + conversion to/from XLA literals.
//!
//! The runtime's lingua franca: every artifact input/output crosses the
//! PJRT boundary as a `Tensor`.  Only f32 and i32 exist in this stack
//! (bf16 is a real-TPU concern; the CPU artifacts are all f32).

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(if shape.is_empty() { 1 } else { 0 })
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; numel(shape)])
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(&[], vec![v])
    }

    pub fn full_f32(shape: &[usize], v: f32) -> Tensor {
        Tensor::f32(shape, vec![v; numel(shape)])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Scalar read (any rank-0 or single-element tensor).
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor with {} elements", d.len());
        }
        Ok(d[0])
    }

    // ------------------------------------------------------- xla bridge
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Tensor::F32 { shape, data } => f32_literal(shape, data),
            Tensor::I32 { shape, data } => i32_literal(shape, data),
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("array shape")?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().context("to_vec f32")?;
                Ok(Tensor::f32(&dims, data))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().context("to_vec i32")?;
                Ok(Tensor::i32(&dims, data))
            }
            other => bail!("unsupported literal type {other:?}"),
        }
    }
}

/// Build an f32 literal straight from a borrowed slice — the serving hot
/// path's upload primitive (no intermediate `Vec`/`Tensor` clone; the
/// literal's own byte copy is the only host copy).
pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    if numel(shape) != data.len() {
        bail!("shape {shape:?} / data len {} mismatch", data.len());
    }
    // SAFETY: reinterpreting &[f32] as &[u8] over the same allocation;
    // len * 4 matches the slice's byte length and u8 has no alignment
    // or validity requirements
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )
    .context("f32 literal")
}

/// i32 twin of [`f32_literal`].
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    if numel(shape) != data.len() {
        bail!("shape {shape:?} / data len {} mismatch", data.len());
    }
    // SAFETY: reinterpreting &[i32] as &[u8] over the same allocation;
    // len * 4 matches the slice's byte length and u8 has no alignment
    // or validity requirements
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )
    .context("i32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_literal_roundtrips_without_tensor() {
        let data = [1.5f32, -2.0, 0.0, 7.25];
        let lit = f32_literal(&[2, 2], &data).unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &data);
        let ints = [3i32, -9];
        let lit = i32_literal(&[2], &ints).unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &ints);
        // shape mismatches are errors, not panics, on the hot path
        assert!(f32_literal(&[3], &data).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(&[4], vec![-1, 0, 7, 42]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_f32(3.5);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.item_f32().unwrap(), 3.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(&[2, 2], vec![1.0]);
    }
}
