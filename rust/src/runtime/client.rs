//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once per artifact,
//! execute from the rust hot path.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Executables are cached per artifact
//! name; compilation happens once per process.
//!
//! Threading: the `xla` crate's handles are not `Send`/`Sync`; the
//! coordinator therefore runs a single engine thread that owns the
//! `Runtime`, and server threads talk to it over channels (see
//! `coordinator::engine`).
//!
//! §Perf — two execution paths share one `execute_b` core:
//!
//! * [`Executable::run_buffers`] is the **host-roundtrip reference
//!   path**: every output materialises to a host literal (the whole
//!   tuple, ~2·B·L·V floats per step at our step-artifact shapes).
//! * [`Executable::run_buffers_device`] is the **device-resident
//!   path**: outputs stay on the device as owned `PjRtBuffer`s, the
//!   session feeds them straight back as the next step's inputs, and
//!   only the tensors the caller asks for cross the boundary through
//!   [`Executable::download_output`] (per step: the `[B]` stat rows).
//!
//! [`ExecStats`] counts bytes at every boundary crossing so
//! `BENCH_serving.json`'s `host_bytes_per_step` can trend the
//! difference (see ROADMAP §Perf: device-resident diffusion state).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Dtype, Manifest};
use super::tensor::Tensor;
use crate::log_info;

/// Cumulative execution counters (perf accounting, EXPERIMENTS.md §Perf).
///
/// The byte counters measure actual host↔device boundary traffic:
/// `upload_bytes` grows at every `buffer_from_host_literal` transfer,
/// `download_bytes` at every literal materialisation of device output —
/// so `(upload_bytes + download_bytes) / executions` is the
/// host-bytes-per-step figure `serving_bench` trends in
/// `BENCH_serving.json`.  The device-resident session path exists to
/// drive this number from O(B·L·V) down to O(B) per step.
#[derive(Default, Debug, Clone, Copy)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub upload_seconds: f64,
    pub download_seconds: f64,
    /// bytes crossing host→device (literal → device buffer transfers)
    pub upload_bytes: u64,
    /// bytes crossing device→host (device output → literal conversions)
    pub download_bytes: u64,
    /// count of [`Executable::download_output`] calls — each is one
    /// device→host sync on the resident path.  The fused-stat design
    /// targets exactly ONE per steady-state step (asserted by
    /// `tests/residency_equivalence.rs`); the split five-row fallback
    /// costs five.
    pub downloads: u64,
}

/// Typed failure of [`Executable::run_buffers_device`]: this PJRT
/// runtime answered the execution with one opaque *tuple* buffer
/// instead of decomposed per-output leaf buffers, so outputs cannot be
/// kept device-resident individually.  `Session` downcasts to this to
/// downgrade gracefully to the host-roundtrip reference path (the
/// downgrade happens before any state is committed, so it is lossless).
#[derive(Clone, Copy, Debug)]
pub struct TupleNotDecomposed {
    /// buffers the runtime returned
    pub got: usize,
    /// leaf outputs the artifact declares
    pub want: usize,
}

impl std::fmt::Display for TupleNotDecomposed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "runtime returned {} output buffer(s) for {} declared outputs \
             (tuple not decomposed) — device-resident outputs unavailable",
            self.got, self.want
        )
    }
}

impl std::error::Error for TupleNotDecomposed {}

/// Host bytes of an array literal (f32/i32 are the only dtypes in this
/// stack, both 4 bytes; scalars count as one element).
fn literal_bytes(lit: &xla::Literal) -> u64 {
    lit.array_shape()
        .map(|s| s.dims().iter().map(|&d| d as u64).product::<u64>() * 4)
        .unwrap_or(0)
}

/// A device buffer plus the host literal backing its (asynchronous)
/// upload — see [`Executable::buffer_from_tensor`].
pub struct DeviceTensor {
    _lit: xla::Literal,
    pub buf: xla::PjRtBuffer,
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    stats: RefCell<ExecStats>,
    /// Pin on the artifact's byte image in the process-wide
    /// [`artifact_cache`](super::artifact_cache): holding it keeps the
    /// mapping resident (evict-while-bound is refused) for as long as
    /// this executable lives; a worker rebind drops the executable and
    /// with it the pin, letting the LRU sweep reclaim the old shape.
    _hlo: Option<super::artifact_cache::Binding>,
}

impl Executable {
    /// Execute with host tensors; returns host tensors (tuple flattened).
    ///
    /// Internally converts through device buffers and `execute_b`: the
    /// xla 0.1.6 crate's `execute()` leaks every input buffer
    /// (`buffer.release()` in xla_rs.cc:900 without a matching free —
    /// ~2 MB/step at our sizes, found via examples/leak_probe.rs), while
    /// `execute_b` borrows caller-owned buffers that free on Drop.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits = self.upload(inputs)?;
        let out = self.run_literals(&lits)?;
        self.download(out)
    }

    /// Upload one tensor to a caller-owned device buffer (freed on Drop).
    ///
    /// The source literal is kept alive inside the returned
    /// [`DeviceTensor`]: `pjrt_buffer_from_host_literal` transfers
    /// asynchronously (no `GetReadyFuture().Await()` on the C side), so
    /// dropping the literal immediately is a use-after-free.
    pub fn buffer_from_tensor(&self, t: &Tensor) -> Result<DeviceTensor> {
        let t0 = Instant::now();
        let lit = t.to_literal()?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .context("buffer_from_host_literal")?;
        let mut s = self.stats.borrow_mut();
        s.upload_seconds += t0.elapsed().as_secs_f64();
        s.upload_bytes += (t.len() * 4) as u64;
        Ok(DeviceTensor { _lit: lit, buf })
    }

    /// Upload a borrowed f32 slice without the `Vec`/`Tensor` clone the
    /// `buffer_from_tensor` path needs — the per-step state upload
    /// (§Perf: the serving hot path calls this 3-5x per device step).
    /// Same literal-lifetime contract as [`Self::buffer_from_tensor`].
    pub fn buffer_from_f32(
        &self,
        shape: &[usize],
        data: &[f32],
    ) -> Result<DeviceTensor> {
        let t0 = Instant::now();
        let lit = super::tensor::f32_literal(shape, data)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .context("buffer_from_host_literal")?;
        let mut s = self.stats.borrow_mut();
        s.upload_seconds += t0.elapsed().as_secs_f64();
        s.upload_bytes += (data.len() * 4) as u64;
        Ok(DeviceTensor { _lit: lit, buf })
    }

    /// i32 twin of [`Self::buffer_from_f32`].
    pub fn buffer_from_i32(
        &self,
        shape: &[usize],
        data: &[i32],
    ) -> Result<DeviceTensor> {
        let t0 = Instant::now();
        let lit = super::tensor::i32_literal(shape, data)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .context("buffer_from_host_literal")?;
        let mut s = self.stats.borrow_mut();
        s.upload_seconds += t0.elapsed().as_secs_f64();
        s.upload_bytes += (data.len() * 4) as u64;
        Ok(DeviceTensor { _lit: lit, buf })
    }

    /// Execute with caller-owned device buffers, materialising every
    /// output to a host literal — the reference (host-roundtrip) path.
    /// Persistent parameter buffers are uploaded once per session and
    /// reused; see [`Self::run_buffers_device`] for the path that keeps
    /// the outputs on the device.
    pub fn run_buffers(
        &self,
        bufs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let row = self.execute_row(bufs)?;
        let t0 = Instant::now();
        // two PJRT output layouts exist in the wild: one opaque tuple
        // buffer (decomposed on the host after materialisation), or
        // already-decomposed per-output leaf buffers.  A single buffer
        // for a single declared output is ambiguous — aot.py lowers
        // with return_tuple=True, so probe the materialised literal
        // (array shape = leaf, else a 1-tuple to decompose).
        let lits: Vec<xla::Literal> = if row.len() == 1 {
            let lit =
                row[0].to_literal_sync().context("to_literal_sync")?;
            if self.spec.outputs.len() == 1 && lit.array_shape().is_ok() {
                vec![lit]
            } else {
                lit.to_tuple().context("tuple decompose")?
            }
        } else {
            let mut lits = Vec::with_capacity(row.len());
            for b in &row {
                lits.push(b.to_literal_sync().context("to_literal_sync")?);
            }
            lits
        };
        let mut s = self.stats.borrow_mut();
        s.download_seconds += t0.elapsed().as_secs_f64();
        s.download_bytes += lits.iter().map(literal_bytes).sum::<u64>();
        Ok(lits)
    }

    /// Execute with caller-owned device buffers and return **owned
    /// output buffers** — nothing is materialised to the host.  The
    /// device-resident serving path feeds these straight back as the
    /// next step's inputs and downloads only the scalar stat rows it
    /// actually reads ([`Self::download_output`]).
    ///
    /// Requires the runtime to hand back decomposed leaf buffers; a
    /// runtime that answers with one opaque tuple buffer fails with a
    /// downcastable [`TupleNotDecomposed`] *before any output crosses
    /// the boundary*, so the caller can fall back to
    /// [`Self::run_buffers`] losslessly.  (Single-output artifacts are
    /// ambiguous under this check and are not driven through the
    /// device path — only multi-output step artifacts are.)
    pub fn run_buffers_device(
        &self,
        bufs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let row = self.execute_row(bufs)?;
        if row.len() != self.spec.outputs.len() {
            return Err(anyhow::Error::new(TupleNotDecomposed {
                got: row.len(),
                want: self.spec.outputs.len(),
            }));
        }
        Ok(row)
    }

    /// Shared execute half of [`Self::run_buffers`] /
    /// [`Self::run_buffers_device`]: arity check, `execute_b`, stats.
    fn execute_row(
        &self,
        bufs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        if bufs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                bufs.len()
            );
        }
        let t0 = Instant::now();
        let out = self
            .exe
            .execute_b(bufs)
            .with_context(|| format!("execute_b {}", self.spec.name))?;
        let row = out
            .into_iter()
            .next()
            .with_context(|| format!("{}: no output row", self.spec.name))?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.exec_seconds += t0.elapsed().as_secs_f64();
        }
        Ok(row)
    }

    /// Materialise ONE device output buffer to a host tensor — the
    /// device-resident path's download primitive (per-step it converts
    /// only the `[B]` stat rows, plus `[B, L]` tokens on demand).
    pub fn download_output(&self, buf: &xla::PjRtBuffer) -> Result<Tensor> {
        let t0 = Instant::now();
        let lit = buf.to_literal_sync().context("to_literal_sync")?;
        let t = Tensor::from_literal(&lit)?;
        let mut s = self.stats.borrow_mut();
        s.download_seconds += t0.elapsed().as_secs_f64();
        s.download_bytes += (t.len() * 4) as u64;
        s.downloads += 1;
        Ok(t)
    }

    /// Validate + convert host tensors to literals (upload half).
    pub fn upload(&self, inputs: &[Tensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact {} input {}: shape {:?} != spec {:?}",
                    self.spec.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            let ok = matches!(
                (t, spec.dtype),
                (Tensor::F32 { .. }, Dtype::F32) | (Tensor::I32 { .. }, Dtype::I32)
            );
            if !ok {
                bail!(
                    "artifact {} input {}: dtype mismatch",
                    self.spec.name,
                    spec.name
                );
            }
            lits.push(t.to_literal()?);
        }
        self.stats.borrow_mut().upload_seconds += t0.elapsed().as_secs_f64();
        Ok(lits)
    }

    /// Execute pre-built literals; returns the raw result literals.
    /// (Routes through owned device buffers + `execute_b`; see `run`.)
    pub fn run_literals(&self, lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let owned: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .context("buffer_from_host_literal")
            })
            .collect::<Result<_>>()?;
        {
            let mut s = self.stats.borrow_mut();
            s.upload_seconds += t0.elapsed().as_secs_f64();
            s.upload_bytes += lits.iter().map(literal_bytes).sum::<u64>();
        }
        let refs: Vec<&xla::PjRtBuffer> = owned.iter().collect();
        // aot.py lowers with return_tuple=True: always a tuple
        self.run_buffers(&refs)
    }

    /// Convert result literals to host tensors (download half).
    pub fn download(&self, lits: Vec<xla::Literal>) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(lits.len());
        for l in &lits {
            out.push(Tensor::from_literal(l)?);
        }
        self.stats.borrow_mut().download_seconds +=
            t0.elapsed().as_secs_f64();
        if out.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest says {}",
                self.spec.name,
                out.len(),
                self.spec.outputs.len()
            );
        }
        Ok(out)
    }

    /// Convert only the selected output indices to host tensors (skips
    /// `Tensor` conversion of bulky literals the caller doesn't need;
    /// the literals themselves were already materialised by
    /// [`Self::run_buffers`], which is where their bytes are counted).
    pub fn download_selected(
        &self,
        lits: &[xla::Literal],
        idxs: &[usize],
    ) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(idxs.len());
        for &i in idxs {
            out.push(Tensor::from_literal(&lits[i])?);
        }
        self.stats.borrow_mut().download_seconds +=
            t0.elapsed().as_secs_f64();
        Ok(out)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &str) -> Result<Runtime> {
        // interned per directory in the process-wide cache: a fleet of
        // N workers parses manifest.json once (the local copy keeps
        // `Runtime.manifest` an owned field — no API ripple)
        let manifest = (*super::artifact_cache::global()
            .manifest(artifact_dir)?)
        .clone();
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        log_info!(
            "PJRT up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Load + compile (cached) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        // Bind the HLO byte image through the process-wide artifact
        // cache: first binder mmaps the file, every other worker shares
        // the mapping (warm pages), and the pin blocks eviction while
        // any executable of this shape is live.  Compilation itself
        // stays path-based (`from_text_file` is the only HLO-text entry
        // point the xla crate exposes) and the compiled executable
        // stays per-runtime — PJRT handles are not `Send`, so the
        // process-wide layer deliberately caches host bytes, not
        // device objects.  A bind failure is non-fatal: compile still
        // proceeds from the path, only unpinned/unaccounted.
        let key = if spec.role == "step" {
            super::artifact_cache::CacheKey::step_hlo(
                &spec.family,
                spec.batch,
                spec.seq_len,
                self.manifest.format,
            )
        } else {
            // non-step artifacts are keyed by their unique name so two
            // roles at one (family, B, L) never collide
            super::artifact_cache::CacheKey {
                family: spec.name.clone(),
                batch: spec.batch,
                seq_len: spec.seq_len,
                format: self.manifest.format,
                kind: super::artifact_cache::ArtifactKind::StepHlo,
            }
        };
        let hlo = match super::artifact_cache::global().bind(&key, &path) {
            Ok(b) => Some(b),
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Warn,
                    "runtime",
                    &format!("artifact cache bind {name}: {e:#}"),
                );
                None
            }
        };
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        log_info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let e = Rc::new(Executable {
            spec,
            exe,
            client: self.client.clone(),
            stats: RefCell::new(ExecStats::default()),
            _hlo: hlo,
        });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Aggregate stats across all cached executables.
    pub fn total_stats(&self) -> ExecStats {
        let mut agg = ExecStats::default();
        for e in self.cache.borrow().values() {
            let s = e.stats();
            agg.executions += s.executions;
            agg.exec_seconds += s.exec_seconds;
            agg.upload_seconds += s.upload_seconds;
            agg.download_seconds += s.download_seconds;
            agg.upload_bytes += s.upload_bytes;
            agg.download_bytes += s.download_bytes;
            agg.downloads += s.downloads;
        }
        agg
    }
}
