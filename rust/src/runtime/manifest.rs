//! `artifacts/manifest.json` loader — the contract between `python -m
//! compile.aot` and the rust runtime: artifact inventory, input signatures
//! (order, shape, dtype) and the flattened parameter-name order per family.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub family: String,
    pub role: String,
    pub batch: usize,
    pub seq_len: usize,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    /// Whether the artifact takes a named input — capability probing
    /// (e.g. format-2 step artifacts carry `prefix_mask`/`prefix_x` for
    /// on-device prefix clamping; format-1 ones don't, and sessions on
    /// them fall back to the host-roundtrip path).
    pub fn has_input(&self, name: &str) -> bool {
        self.inputs.iter().any(|i| i.name == name)
    }

    /// Index of a named input in the artifact's flat input list.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input {name}", self.name))
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow!("artifact {} has no output {name}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub simplex_k: f32,
    pub t_max: f32,
    pub t_min: f32,
    pub tw_buckets: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// manifest schema version (`"format"`; absent = 1).  Format 2 step
    /// artifacts carry the on-device prefix-clamp inputs that enable
    /// the session's device-resident state path; capability is probed
    /// per artifact via [`ArtifactSpec::has_input`], so a format-1
    /// manifest (or a hand-pruned artifact) transparently serves
    /// through the host-roundtrip reference path instead.  Format 3
    /// step artifacts additionally emit a fused `stats_fused`
    /// `[B, 5+2L]` output (the five `[B]` stat rows stacked with the
    /// per-position token-entropy and argmax-changed lanes), appended
    /// LAST so format-2 output indices never shift; sessions probe it
    /// via [`ArtifactSpec::output_index`] and fall back to the
    /// five-row split download (token halting unavailable) when absent.
    pub format: u64,
    pub model: ModelDims,
    pub param_names: BTreeMap<String, Vec<String>>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;

        let format = j.get("format").and_then(Json::as_u64).unwrap_or(1);
        let m = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let dim = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model.{k} missing"))
        };
        let fdim = |k: &str| -> Result<f32> {
            m.get(k)
                .and_then(Json::as_f64)
                .map(|v| v as f32)
                .ok_or_else(|| anyhow!("model.{k} missing"))
        };
        let model = ModelDims {
            vocab: dim("vocab")?,
            seq_len: dim("seq_len")?,
            d_model: dim("d_model")?,
            n_layers: dim("n_layers")?,
            n_heads: dim("n_heads")?,
            d_ff: dim("d_ff")?,
            simplex_k: fdim("simplex_k")?,
            t_max: fdim("t_max")?,
            t_min: fdim("t_min")?,
            tw_buckets: dim("tw_buckets")?,
        };

        let mut param_names = BTreeMap::new();
        if let Some(Json::Obj(pn)) = j.get("param_names") {
            for (fam, arr) in pn {
                let names = arr
                    .as_arr()
                    .ok_or_else(|| anyhow!("param_names.{fam} not array"))?
                    .iter()
                    .map(|x| x.as_str().unwrap_or_default().to_string())
                    .collect();
                param_names.insert(fam.clone(), names);
            }
        }

        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let s = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact.{k} missing"))
            };
            let mut inputs = Vec::new();
            for i in a
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("artifact inputs missing"))?
            {
                let dtype = match i.get("dtype").and_then(Json::as_str) {
                    Some("i32") => Dtype::I32,
                    _ => Dtype::F32,
                };
                let shape = i
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("input shape missing"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                inputs.push(InputSpec {
                    name: i
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    shape,
                    dtype,
                });
            }
            let outputs = a
                .get("outputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("artifact outputs missing"))?
                .iter()
                .map(|o| o.as_str().unwrap_or_default().to_string())
                .collect();
            let spec = ArtifactSpec {
                name: s("name")?,
                file: s("file")?,
                family: s("family")?,
                role: s("role")?,
                batch: a
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact batch missing"))?,
                seq_len: a
                    .get("seq_len")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact seq_len missing"))?,
                inputs,
                outputs,
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        Ok(Manifest {
            dir,
            format,
            model,
            param_names,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name} (have: {:?})",
                                   self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    pub fn params_of(&self, family: &str) -> Result<&[String]> {
        self.param_names
            .get(family)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("no param names for family {family}"))
    }

    /// Pick the step artifact for (family, batch, seq_len).
    pub fn step_artifact(
        &self,
        family: &str,
        batch: usize,
        seq_len: usize,
    ) -> Result<&ArtifactSpec> {
        self.artifact(&format!("{family}_step_b{batch}_l{seq_len}"))
    }

    /// Batch sizes for which a step artifact exists (ascending).
    pub fn available_step_batches(
        &self,
        family: &str,
        seq_len: usize,
    ) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| {
                a.family == family && a.role == "step" && a.seq_len == seq_len
            })
            .map(|a| a.batch)
            .collect();
        out.sort_unstable();
        out
    }

    /// Smallest available step batch >= `want` (or the largest overall).
    pub fn resolve_step_batch(
        &self,
        family: &str,
        seq_len: usize,
        want: usize,
    ) -> Result<usize> {
        let avail = self.available_step_batches(family, seq_len);
        if avail.is_empty() {
            return Err(anyhow!(
                "no step artifacts for {family} at seq_len {seq_len}"
            ));
        }
        Ok(avail
            .iter()
            .copied()
            .find(|&b| b >= want)
            .unwrap_or(*avail.last().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert!(m.artifacts.contains_key("ddlm_step_b8_l64"));
        let a = m.artifact("ddlm_step_b8_l64").unwrap();
        // jax prunes unused params at lowering, so kept inputs <= full
        // set (4 legacy data inputs + 2 format-2 prefix-clamp inputs)
        let n_params = m.params_of("ddlm").unwrap().len();
        assert!(a.inputs.len() > 4 && a.inputs.len() <= n_params + 6);
        // freshly-built artifacts are format 2: on-device prefix clamp
        assert!(m.format >= 2, "format {}", m.format);
        assert!(a.has_input("prefix_mask") && a.has_input("prefix_x"));
        assert!(!a.has_input("bogus"));
        assert_eq!(a.output_index("entropy").unwrap(), 4);
        // format-3 fused stat tensor rides LAST so the format-2
        // positional indices above stay pinned
        if m.format >= 3 {
            assert_eq!(
                a.output_index("stats_fused").unwrap(),
                a.outputs.len() - 1
            );
        }
        // x_t input: [8, 64, 64] f32
        let xi = a.input_index("x_t").unwrap();
        assert_eq!(a.inputs[xi].shape, vec![8, 64, 64]);
        assert_eq!(a.inputs[xi].dtype, Dtype::F32);
    }
}
