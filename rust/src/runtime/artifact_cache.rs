//! Process-wide artifact cache: memory-mapped artifact bytes shared by
//! every worker, keyed by (family, B, L, format), with LRU eviction
//! under a byte budget — the layer that makes drain→rebind→rejoin
//! cheap enough to drive elastically (ROADMAP item 4).
//!
//! What is cached (and what is not):
//!
//! * **Bytes, not device objects.**  Entries are immutable read-only
//!   byte images — mmap'd HLO text, mmap'd `.pbin` checkpoints (fed
//!   straight to [`crate::models::pbin::parse`] without a heap copy),
//!   and parsed [`Manifest`]s (interned per directory).  Compiled
//!   executables and PJRT buffers stay in each worker's per-runtime
//!   cache: PJRT handles are not `Send`, so the process-wide layer
//!   deliberately stops at the host-byte boundary.
//! * **Bindings pin entries.**  [`ArtifactCache::bind`] returns a
//!   [`Binding`] guard; while any binding is alive the entry cannot be
//!   evicted (`evict` on a pinned key is a typed refusal, and the LRU
//!   sweep skips pinned entries even over budget).  A worker holds one
//!   binding per bound artifact and drops it on rebind, which is what
//!   lets the sweep reclaim the old shape's bytes.
//! * **Concurrent binds load once.**  The first binder inserts a
//!   loading placeholder and maps the file outside the lock; racers
//!   wait on a condvar and share the same mapping (`Arc`), so N
//!   workers binding one artifact cost one mmap.
//!
//! Eviction is strict LRU over unpinned entries: entries are stamped
//! with a monotone tick on every bind, and once `bytes > budget` the
//! stalest unpinned entries unmap until the budget holds (a pinned
//! over-budget working set is allowed — refusing eviction beats
//! breaking a live worker).  Hit/miss/evict/byte counters feed the
//! fleet metrics snapshot as `artifact_cache_*`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Manifest;
use crate::util::sync::{lock_or_recover, wait_or_recover};

/// What a cached byte image is, distinguishing the step-graph HLO text
/// from checkpoint weights at the same (family, B, L).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// compiled-step HLO text (`<fam>_step_b<B>_l<L>.hlo.txt`)
    StepHlo,
    /// parameter checkpoint bytes (`.pbin`)
    Checkpoint,
}

impl ArtifactKind {
    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::StepHlo => "step_hlo",
            ArtifactKind::Checkpoint => "checkpoint",
        }
    }
}

/// Cache key: the artifact-shape coordinates the fleet rebinds over.
/// Checkpoints that are not shape-specific use `batch == 0 &&
/// seq_len == 0` (a `.pbin` serves every compiled shape of its family).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub family: String,
    pub batch: usize,
    pub seq_len: usize,
    /// manifest schema format the artifact was built under
    pub format: u64,
    pub kind: ArtifactKind,
}

impl CacheKey {
    pub fn step_hlo(
        family: &str,
        batch: usize,
        seq_len: usize,
        format: u64,
    ) -> CacheKey {
        CacheKey {
            family: family.to_string(),
            batch,
            seq_len,
            format,
            kind: ArtifactKind::StepHlo,
        }
    }

    pub fn checkpoint(family: &str, path: &Path) -> CacheKey {
        // distinct checkpoint files of one family (init vs trained vs
        // ck-marks) must not collide: fold the path into the family
        // coordinate, keeping the shape axes for the shape-free weights
        CacheKey {
            family: format!("{family}@{}", path.display()),
            batch: 0,
            seq_len: 0,
            format: 0,
            kind: ArtifactKind::Checkpoint,
        }
    }

    fn describe(&self) -> String {
        format!(
            "{}[{} b{} l{} f{}]",
            self.kind.name(),
            self.family,
            self.batch,
            self.seq_len,
            self.format
        )
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// An immutable byte image: a private read-only file mapping on unix,
/// or an owned heap copy (empty files, non-unix targets, mmap failure).
/// The mapping is unmapped on drop.
pub struct MappedBytes {
    ptr: *const u8,
    len: usize,
    /// true = `ptr` is an mmap region to munmap; false = `owned` backs it
    mapped: bool,
    owned: Vec<u8>,
}

// SAFETY: the region is a private read-only mapping (or an owned Vec)
// that is never written after construction; moving the owner across
// threads moves only the pointer, and munmap runs exactly once via Drop.
unsafe impl Send for MappedBytes {}
// SAFETY: all shared access is through `&[u8]` views of memory that is
// immutable after construction, so concurrent readers cannot race.
unsafe impl Sync for MappedBytes {}

impl MappedBytes {
    fn from_vec(data: Vec<u8>) -> MappedBytes {
        MappedBytes {
            ptr: data.as_ptr(),
            len: data.len(),
            mapped: false,
            owned: data,
        }
    }

    #[cfg(unix)]
    fn try_map(path: &Path) -> Result<MappedBytes> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            // zero-length mmap is EINVAL; an empty image needs no map
            return Ok(MappedBytes::from_vec(Vec::new()));
        }
        // SAFETY: fd is open for the whole call, len > 0 was checked,
        // and a MAP_PRIVATE|PROT_READ mapping aliases no Rust memory;
        // MAP_FAILED is handled below before the pointer is used
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            bail!("mmap {path:?} failed");
        }
        Ok(MappedBytes {
            ptr: ptr as *const u8,
            len,
            mapped: true,
            owned: Vec::new(),
        })
    }

    /// Map a file read-only; falls back to a buffered read when the
    /// platform or the mapping refuses.
    pub fn open(path: &Path) -> Result<MappedBytes> {
        #[cfg(unix)]
        {
            match MappedBytes::try_map(path) {
                Ok(m) => return Ok(m),
                Err(e) => crate::util::log::log(
                    crate::util::log::Level::Debug,
                    "artifact_cache",
                    &format!("{e:#}; falling back to a buffered read"),
                ),
            }
        }
        let data = std::fs::read(path)
            .with_context(|| format!("read {path:?}"))?;
        Ok(MappedBytes::from_vec(data))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when the image is an actual mmap region (not a heap copy).
    pub fn is_mapped(&self) -> bool {
        self.mapped
    }
}

impl std::ops::Deref for MappedBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        if self.mapped {
            // SAFETY: ptr/len describe a live PROT_READ mapping owned
            // by self; unmapped only in Drop
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        } else {
            &self.owned
        }
    }
}

impl Drop for MappedBytes {
    fn drop(&mut self) {
        if self.mapped {
            // SAFETY: ptr/len came from a successful mmap of this
            // owner and `mapped` guarantees this is the only munmap
            unsafe {
                sys::munmap(self.ptr as *mut _, self.len);
            }
        }
    }
}

impl std::fmt::Debug for MappedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MappedBytes({} bytes, {})",
            self.len,
            if self.mapped { "mmap" } else { "owned" }
        )
    }
}

/// Counter snapshot surfaced as `artifact_cache_*` in the fleet
/// metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// resident cached bytes right now
    pub bytes: u64,
    pub entries: usize,
}

enum Slot {
    /// first binder is mapping the file; racers wait on the condvar
    Loading,
    Ready {
        bytes: Arc<MappedBytes>,
        pins: usize,
        last_used: u64,
    },
}

struct State {
    entries: HashMap<CacheKey, Slot>,
    manifests: HashMap<PathBuf, Arc<Manifest>>,
    bytes_total: u64,
    budget: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

struct Inner {
    state: Mutex<State>,
    loaded: Condvar,
}

/// The cache handle (cheap to clone; all clones share one store).  Use
/// [`global`] for the process-wide instance workers bind through.
#[derive(Clone)]
pub struct ArtifactCache {
    inner: Arc<Inner>,
}

/// A pinned cache entry: the artifact bytes, guaranteed resident (and
/// un-evictable) until this guard drops.
pub struct Binding {
    inner: Arc<Inner>,
    key: CacheKey,
    bytes: Arc<MappedBytes>,
}

impl Binding {
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn key(&self) -> &CacheKey {
        &self.key
    }

    /// Shared mapping identity — two bindings of one key hold the SAME
    /// mapping (the "no duplicate mmap" contract).
    pub fn same_mapping(&self, other: &Binding) -> bool {
        Arc::ptr_eq(&self.bytes, &other.bytes)
    }
}

impl Drop for Binding {
    fn drop(&mut self) {
        let mut st = lock_or_recover(&self.inner.state);
        if let Some(Slot::Ready { pins, .. }) = st.entries.get_mut(&self.key)
        {
            *pins = pins.saturating_sub(1);
        }
        // an unpin can make an over-budget working set reclaimable
        sweep_lru(&mut st);
    }
}

impl std::fmt::Debug for Binding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Binding({}, {} bytes)", self.key.describe(), self.bytes.len())
    }
}

/// Evict stalest unpinned entries until the byte budget holds.  Pinned
/// entries are never touched: a bound working set larger than the
/// budget stays resident (refusing eviction beats breaking a worker).
fn sweep_lru(st: &mut State) {
    while st.bytes_total > st.budget {
        let victim = st
            .entries
            .iter()
            .filter_map(|(k, slot)| match slot {
                Slot::Ready { pins: 0, last_used, bytes } => {
                    Some((k.clone(), *last_used, bytes.len() as u64))
                }
                _ => None,
            })
            .min_by_key(|&(_, last_used, _)| last_used);
        let Some((key, _, len)) = victim else { break };
        st.entries.remove(&key);
        st.bytes_total -= len;
        st.evictions += 1;
    }
}

impl ArtifactCache {
    pub fn new(budget_bytes: u64) -> ArtifactCache {
        ArtifactCache {
            inner: Arc::new(Inner {
                state: Mutex::new(State {
                    entries: HashMap::new(),
                    manifests: HashMap::new(),
                    bytes_total: 0,
                    budget: budget_bytes,
                    tick: 0,
                    hits: 0,
                    misses: 0,
                    evictions: 0,
                }),
                loaded: Condvar::new(),
            }),
        }
    }

    /// Bind an artifact: return its (pinned) byte image, mapping the
    /// file on first touch.  Concurrent binds of one key share a single
    /// load; a failed load wakes the racers to retry (one of them
    /// becomes the next loader and surfaces the error to its caller).
    pub fn bind(&self, key: &CacheKey, path: &Path) -> Result<Binding> {
        {
            let mut st = lock_or_recover(&self.inner.state);
            loop {
                match st.entries.get_mut(key) {
                    Some(Slot::Ready { bytes, pins, last_used }) => {
                        *pins += 1;
                        st.tick += 1;
                        *last_used = st.tick;
                        st.hits += 1;
                        return Ok(Binding {
                            inner: self.inner.clone(),
                            key: key.clone(),
                            bytes: bytes.clone(),
                        });
                    }
                    Some(Slot::Loading) => {
                        st = wait_or_recover(&self.inner.loaded, st);
                    }
                    None => {
                        st.misses += 1;
                        st.entries.insert(key.clone(), Slot::Loading);
                        break;
                    }
                }
            }
        }
        // this caller owns the load; map outside the lock.  The
        // `cache_mmap` chaos hook fails the load exactly like a real
        // mmap error: the Loading slot is cleared, racers retry, the
        // caller gets a typed error.
        let mapped = if crate::util::fault::check("cache_mmap").is_some() {
            Err(anyhow::anyhow!(
                "injected cache_mmap fault loading {}",
                key.describe()
            ))
        } else {
            MappedBytes::open(path)
                .with_context(|| format!("load {}", key.describe()))
        };
        let mut st = lock_or_recover(&self.inner.state);
        match mapped {
            Err(e) => {
                st.entries.remove(key);
                self.inner.loaded.notify_all();
                Err(e)
            }
            Ok(m) => {
                let bytes = Arc::new(m);
                st.bytes_total += bytes.len() as u64;
                st.tick += 1;
                let tick = st.tick;
                st.entries.insert(
                    key.clone(),
                    Slot::Ready {
                        bytes: bytes.clone(),
                        pins: 1,
                        last_used: tick,
                    },
                );
                sweep_lru(&mut st);
                self.inner.loaded.notify_all();
                Ok(Binding {
                    inner: self.inner.clone(),
                    key: key.clone(),
                    bytes,
                })
            }
        }
    }

    /// Explicitly evict one entry.  Refused (typed error) while any
    /// binding pins it — eviction never pulls bytes out from under a
    /// bound worker.
    pub fn evict(&self, key: &CacheKey) -> Result<()> {
        let mut st = lock_or_recover(&self.inner.state);
        match st.entries.get(key) {
            None => Ok(()),
            Some(Slot::Loading) => {
                bail!("evict {}: load in flight", key.describe())
            }
            Some(Slot::Ready { pins, .. }) if *pins > 0 => Err(anyhow!(
                "evict {}: refused, {pins} live binding(s)",
                key.describe()
            )),
            Some(Slot::Ready { bytes, .. }) => {
                let len = bytes.len() as u64;
                st.entries.remove(key);
                st.bytes_total -= len;
                st.evictions += 1;
                Ok(())
            }
        }
    }

    /// Change the byte budget; shrinking sweeps immediately.
    pub fn set_budget(&self, budget_bytes: u64) {
        let mut st = lock_or_recover(&self.inner.state);
        st.budget = budget_bytes;
        sweep_lru(&mut st);
    }

    pub fn stats(&self) -> CacheStats {
        let st = lock_or_recover(&self.inner.state);
        CacheStats {
            hits: st.hits,
            misses: st.misses,
            evictions: st.evictions,
            bytes: st.bytes_total,
            entries: st.entries.len(),
        }
    }

    /// Parsed manifest for an artifact directory, interned per
    /// canonical path — N workers of one fleet parse `manifest.json`
    /// once.  Manifests are small and config-like: they live outside
    /// the byte budget and are never evicted.
    pub fn manifest(&self, dir: impl AsRef<Path>) -> Result<Arc<Manifest>> {
        let dir = dir.as_ref();
        let canon =
            std::fs::canonicalize(dir).unwrap_or_else(|_| dir.to_path_buf());
        if let Some(m) =
            lock_or_recover(&self.inner.state).manifests.get(&canon)
        {
            return Ok(m.clone());
        }
        // parse outside the lock; a racing double-parse is harmless
        // (last writer wins, both Arcs are equivalent)
        let m = Arc::new(Manifest::load(dir)?);
        lock_or_recover(&self.inner.state)
            .manifests
            .insert(canon, m.clone());
        Ok(m)
    }
}

fn default_budget() -> u64 {
    const DEFAULT: u64 = 1 << 30; // 1 GiB
    std::env::var("REPRO_ARTIFACT_CACHE_BYTES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(DEFAULT)
}

static GLOBAL: OnceLock<ArtifactCache> = OnceLock::new();

/// The process-wide cache every worker binds through.  Budget comes
/// from `REPRO_ARTIFACT_CACHE_BYTES` (default 1 GiB); operators resize
/// it live via [`ArtifactCache::set_budget`] (`--artifact-cache-mb`).
pub fn global() -> &'static ArtifactCache {
    GLOBAL.get_or_init(|| ArtifactCache::new(default_budget()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "repro_artifact_cache_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_file(dir: &Path, name: &str, len: usize) -> PathBuf {
        let p = dir.join(name);
        std::fs::write(&p, vec![0x5a; len]).unwrap();
        p
    }

    fn key(tag: &str, batch: usize) -> CacheKey {
        CacheKey::step_hlo(tag, batch, 64, 3)
    }

    #[test]
    fn bind_maps_and_counts_hits_and_misses() {
        let dir = tmp_dir("hits");
        let p = write_file(&dir, "a.hlo.txt", 100);
        let c = ArtifactCache::new(1 << 20);
        let b1 = c.bind(&key("a", 8), &p).unwrap();
        assert_eq!(b1.bytes().len(), 100);
        assert_eq!(b1.bytes()[0], 0x5a);
        let b2 = c.bind(&key("a", 8), &p).unwrap();
        // the SAME mapping is shared — no duplicate mmap
        assert!(b1.same_mapping(&b2));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(s.bytes, 100);
        assert_eq!(s.entries, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_a_typed_error_and_leaves_no_residue() {
        let dir = tmp_dir("missing");
        let c = ArtifactCache::new(1 << 20);
        let e = c.bind(&key("nope", 1), &dir.join("absent")).unwrap_err();
        assert!(format!("{e:#}").contains("step_hlo"), "{e:#}");
        assert_eq!(c.stats().entries, 0);
        // the failed load slot is cleaned up: a later bind retries
        let p = write_file(&dir, "absent", 10);
        assert_eq!(c.bind(&key("nope", 1), &p).unwrap().bytes().len(), 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_evicts_in_staleness_order_under_byte_budget() {
        let dir = tmp_dir("lru");
        let pa = write_file(&dir, "a", 400);
        let pb = write_file(&dir, "b", 400);
        let pc = write_file(&dir, "c", 400);
        let c = ArtifactCache::new(1000);
        drop(c.bind(&key("a", 1), &pa).unwrap());
        drop(c.bind(&key("b", 1), &pb).unwrap());
        // touch a, so b is now the stalest
        drop(c.bind(&key("a", 1), &pa).unwrap());
        // c overflows the 1000-byte budget: b (stalest unpinned) goes
        drop(c.bind(&key("c", 1), &pc).unwrap());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.bytes, 800);
        // a survives (hit), b was evicted (miss → reload)
        drop(c.bind(&key("a", 1), &pa).unwrap());
        let hits_before = c.stats().hits;
        drop(c.bind(&key("b", 1), &pb).unwrap());
        let s = c.stats();
        assert_eq!(s.hits, hits_before, "b must have been evicted");
        // the reload of b pushed bytes to 1200 again: LRU swept c or a
        assert!(s.bytes <= 1000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pinned_entries_are_never_evicted_and_evict_is_refused() {
        let dir = tmp_dir("pin");
        let pa = write_file(&dir, "a", 600);
        let pb = write_file(&dir, "b", 600);
        let c = ArtifactCache::new(1000);
        let bound = c.bind(&key("a", 1), &pa).unwrap();
        // over budget, but a is pinned: it must survive the sweep
        let b2 = c.bind(&key("b", 1), &pb).unwrap();
        drop(b2); // unpinning b lets the sweep reclaim it instead
        let s = c.stats();
        assert!(
            c.stats().bytes >= 600,
            "pinned entry evicted: {s:?}"
        );
        let hits = c.stats().hits;
        drop(c.bind(&key("a", 1), &pa).unwrap());
        assert_eq!(c.stats().hits, hits + 1, "a must still be resident");
        // explicit evict of a bound key is a typed refusal
        let e = c.evict(&key("a", 1)).unwrap_err();
        assert!(e.to_string().contains("refused"), "{e}");
        // once the binding drops, evict succeeds
        drop(bound);
        c.evict(&key("a", 1)).unwrap();
        assert_eq!(c.stats().entries, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_binds_of_one_key_load_once() {
        let dir = tmp_dir("concurrent");
        let p = write_file(&dir, "big", 4096);
        let c = ArtifactCache::new(1 << 20);
        let started = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                let p = p.clone();
                let started = started.clone();
                std::thread::spawn(move || {
                    started.fetch_add(1, Ordering::SeqCst);
                    // spin until every thread is poised to bind
                    while started.load(Ordering::SeqCst) < 8 {
                        std::hint::spin_loop();
                    }
                    c.bind(&key("big", 8), &p).unwrap()
                })
            })
            .collect();
        let bindings: Vec<Binding> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let s = c.stats();
        assert_eq!(s.misses, 1, "one load for 8 concurrent binds: {s:?}");
        assert_eq!(s.hits, 7);
        assert_eq!(s.bytes, 4096, "one mapping resident, not 8");
        for b in &bindings[1..] {
            assert!(bindings[0].same_mapping(b), "duplicate mmap");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_shrink_sweeps_immediately() {
        let dir = tmp_dir("shrink");
        let pa = write_file(&dir, "a", 300);
        let pb = write_file(&dir, "b", 300);
        let c = ArtifactCache::new(1 << 20);
        drop(c.bind(&key("a", 1), &pa).unwrap());
        drop(c.bind(&key("b", 1), &pb).unwrap());
        assert_eq!(c.stats().bytes, 600);
        c.set_budget(400);
        let s = c.stats();
        assert_eq!(s.bytes, 300, "shrink must sweep the stalest: {s:?}");
        assert_eq!(s.evictions, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_keys_fold_in_the_path() {
        let a = CacheKey::checkpoint("ddlm", Path::new("runs/ddlm.pbin"));
        let b = CacheKey::checkpoint("ddlm", Path::new("runs/ddlm_ck75.pbin"));
        assert_ne!(a, b);
        assert_eq!(a, CacheKey::checkpoint("ddlm", Path::new("runs/ddlm.pbin")));
    }

    #[test]
    fn manifest_interning_parses_once_per_dir() {
        let dir = tmp_dir("manifest");
        // a minimal but valid manifest
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":3,"model":{"vocab":8,"seq_len":4,"d_model":2,
                "n_layers":1,"n_heads":1,"d_ff":4,"simplex_k":1.0,
                "t_max":10.0,"t_min":0.05,"tw_buckets":4},
                "artifacts":[]}"#,
        )
        .unwrap();
        let c = ArtifactCache::new(1 << 20);
        let m1 = c.manifest(&dir).unwrap();
        let m2 = c.manifest(&dir).unwrap();
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(m1.format, 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
