//! PJRT runtime layer: manifest loading, host tensors, executable cache.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, ExecStats, Runtime};
pub use manifest::{ArtifactSpec, Dtype, Manifest};
pub use tensor::Tensor;
