//! PJRT runtime layer: manifest loading, host tensors, executable cache,
//! and the process-wide mmap-backed artifact cache workers rebind through.

pub mod artifact_cache;
pub mod client;
pub mod manifest;
pub mod tensor;

pub use artifact_cache::{ArtifactCache, ArtifactKind, Binding, CacheKey, CacheStats};
pub use client::{Executable, ExecStats, Runtime};
pub use manifest::{ArtifactSpec, Dtype, Manifest};
pub use tensor::Tensor;
