//! Deterministic PRNG substrate (SplitMix64 seeding + xoshiro256**).
//!
//! The image has no `rand` crate, and the experiments need *reproducible*
//! streams that can be forked per purpose (data order, diffusion noise,
//! request arrivals) without cross-contamination — so forkability is a
//! first-class feature: [`Prng::fork`] derives an independent child stream
//! from a label, mirroring JAX's key-splitting discipline.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// cached second gaussian from the Box-Muller pair
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s, spare: None }
    }

    /// Derive an independent child stream from a string label.
    /// Same (parent seed, label) -> same child, different labels ->
    /// statistically independent children.
    pub fn fork(&self, label: &str) -> Prng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a over the label
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        // mix with our own next output so sibling forks of forks differ
        let mut base = self.s[0] ^ self.s[2];
        let mixed = splitmix64(&mut base) ^ h;
        Prng::new(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // take the top 53 bits for a dyadic uniform
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // rejection-free Lemire-style mapping; bias is negligible for the
        // corpus sizes used here but we keep the widening multiply exact.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (caches the pair's second value).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a buffer with standard gaussians (f32).
    pub fn fill_gaussian_f32(&mut self, buf: &mut [f32]) {
        for x in buf.iter_mut() {
            *x = self.gaussian() as f32;
        }
    }

    /// Vector of n standard gaussians (f32).
    pub fn gaussian_vec_f32(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_gaussian_f32(&mut v);
        v
    }

    /// Sample an index from unnormalised nonnegative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_is_stable_and_label_sensitive() {
        let root = Prng::new(7);
        let mut c1 = root.fork("noise");
        let mut c2 = root.fork("noise");
        let mut c3 = root.fork("data");
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Prng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Prng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_property() {
        let mut r = Prng::new(9);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn weighted_respects_zero_mass() {
        let mut r = Prng::new(11);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
