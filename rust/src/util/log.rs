//! Leveled stderr logger with wall-clock offsets (no `log`/`env_logger`
//! wiring needed for a single-binary launcher; `REPRO_LOG=debug` bumps
//! verbosity).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialise from the environment; call once at startup (idempotent).
pub fn init() {
    let lvl = match std::env::var("REPRO_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    let _ = start();
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
