//! TOML-lite config substrate (no serde/toml crates offline).
//!
//! Parses the subset of TOML the launcher's config files use: `[section]`
//! headers, `key = value` with string / number / bool / inline string
//! arrays, and `#` comments.  Lookup is by `"section.key"` with typed
//! accessors and defaults, so experiment configs stay declarative:
//!
//! ```toml
//! [serve]
//! batch = 8
//! criterion = "kl"
//! threshold = 5e-3
//! ```

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    StrArr(Vec<String>),
    NumArr(Vec<f64>),
}

#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            cfg.entries.insert(
                key,
                parse_value(v.trim())
                    .map_err(|e| format!("line {}: {}", lineno + 1, e))?,
            );
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.entries.get(key) {
            Some(Value::Str(s)) => s,
            _ => default,
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.entries.get(key) {
            Some(Value::Num(n)) => *n,
            _ => default,
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.f64_or(key, default as f64) as usize
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.entries.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// Merge CLI overrides of the form `section.key=value`.
    pub fn override_kv(&mut self, spec: &str) -> Result<(), String> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| format!("bad override {spec:?}"))?;
        self.entries
            .insert(k.trim().to_string(), parse_value(v.trim())?);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .ok_or_else(|| format!("bad string {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or_else(|| format!("bad array {s:?}"))?;
        let parts: Vec<&str> = inner
            .split(',')
            .map(|p| p.trim())
            .filter(|p| !p.is_empty())
            .collect();
        if parts.iter().all(|p| p.starts_with('"')) {
            let mut out = Vec::new();
            for p in parts {
                match parse_value(p)? {
                    Value::Str(x) => out.push(x),
                    _ => return Err(format!("mixed array {s:?}")),
                }
            }
            return Ok(Value::StrArr(out));
        }
        let mut out = Vec::new();
        for p in parts {
            out.push(
                p.parse::<f64>()
                    .map_err(|_| format!("bad number {p:?} in array"))?,
            );
        }
        return Ok(Value::NumArr(out));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("unparseable value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
title = "repro"     # trailing comment
[serve]
batch = 8
threshold = 5e-3
adaptive = true
criteria = ["kl", "entropy"]
steps = [50, 200, 1000]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("title", ""), "repro");
        assert_eq!(c.usize_or("serve.batch", 0), 8);
        assert_eq!(c.f64_or("serve.threshold", 0.0), 5e-3);
        assert!(c.bool_or("serve.adaptive", false));
        assert_eq!(
            c.get("serve.criteria"),
            Some(&Value::StrArr(vec!["kl".into(), "entropy".into()]))
        );
        assert_eq!(
            c.get("serve.steps"),
            Some(&Value::NumArr(vec![50.0, 200.0, 1000.0]))
        );
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.override_kv("serve.batch=16").unwrap();
        c.override_kv("serve.criterion=\"patience\"").unwrap();
        assert_eq!(c.usize_or("serve.batch", 0), 16);
        assert_eq!(c.str_or("serve.criterion", ""), "patience");
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 3), 3);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = @@").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str_or("k", ""), "a#b");
    }
}
