//! Plain-text table rendering for experiment/bench output — every `exp`
//! module prints the same rows the paper's tables/figures report, through
//! this one formatter (keeps bench output grep-able and diff-able).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity must match headers"
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        out.push_str(&line(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with a fixed number of decimals (table cells).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Render a crude ASCII sparkline of a series (figures-as-text).
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let step = (values.len() as f64 / width.max(1) as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && out.chars().count() < width {
        let v = values[i as usize];
        let idx = (((v - lo) / span) * 7.0).round() as usize;
        out.push(GLYPHS[idx.min(7)]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("name") && lines[0].contains("value"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sparkline_monotone() {
        let vals: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let s = sparkline(&vals, 16);
        assert_eq!(s.chars().count(), 16);
        assert!(s.starts_with('▁') && s.ends_with('█'));
    }
}
