//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! which covers the launcher's subcommands (`repro train ... --steps 500`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.usize_or(name, default as usize) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--steps", "500", "--lr=0.003", "--quiet"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("steps"), Some("500"));
        assert_eq!(a.f64_or("lr", 0.0), 0.003);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_after_positional() {
        // NOTE: a bare `--x` followed by a non-flag token is parsed as
        // `--x <value>` (documented greedy rule); boolean flags therefore
        // go last or before another `--` token.
        let a = parse(&["gen", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["gen"]);
    }

    #[test]
    fn option_consumes_next_token_only_if_not_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.get_or("mode", "euler"), "euler");
    }
}
