//! Hand-rolled substrates the offline image forces us to own (DESIGN.md §8):
//! PRNG, JSON, TOML-lite config, CLI parsing, logging, table rendering.

pub mod cli;
pub mod config;
pub mod fault;
pub mod json;
pub mod log;
pub mod prng;
pub mod sync;
pub mod table;
