//! Poison-tolerant lock helpers for the serving stack.
//!
//! A worker thread that panics while holding a shared `Mutex` (the
//! scheduler state, a metrics shard, the artifact-cache store) poisons
//! it; every later `.lock().unwrap()` on that mutex then panics too,
//! cascading one failure into fleet-wide death.  The serving stack's
//! shared state is counter/gauge bookkeeping and queue structure that
//! is valid at every statement boundary — a panicked holder may leave
//! a *stale* value, never a torn one — so recovery (take the guard,
//! keep serving) strictly beats propagation.
//!
//! [`lock_or_recover`] is therefore the ONLY way serving-path code
//! acquires a mutex (`repro analyze` enforces this: bare
//! `.lock().unwrap()` is the `lock-poison` check).  Every recovery is
//! counted; the fleet metrics snapshot surfaces the counter as
//! `lock_poisoned` (absent until nonzero) so an operator can tell
//! "survived a poisoned lock N times" from "never happened".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// Total poisoned acquisitions recovered process-wide (lock + condvar
/// re-acquisitions).  Monotone; surfaced as `lock_poisoned`.
static LOCK_POISONED: AtomicU64 = AtomicU64::new(0);

/// Acquire `m`, recovering the guard if a previous holder panicked.
/// The poison flag is cleared so the mutex goes back to the fast path;
/// each recovery increments the process-wide [`poisoned_count`].
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            LOCK_POISONED.fetch_add(1, Ordering::Relaxed);
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// [`Condvar::wait`] that recovers the re-acquired guard if the mutex
/// was poisoned while this thread slept.  The caller's next
/// [`lock_or_recover`] clears the flag; the recovery is counted here.
pub fn wait_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => {
            LOCK_POISONED.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

/// [`Condvar::wait_timeout`] that recovers the re-acquired guard if
/// the mutex was poisoned while this thread slept.  Used where a
/// waiter must wake on a *deadline* nobody will notify for (e.g. a
/// retry-backoff expiry); the timeout flag is dropped because callers
/// re-check their predicate either way.
pub fn wait_timeout_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => {
            LOCK_POISONED.fetch_add(1, Ordering::Relaxed);
            let (g, _) = poisoned.into_inner();
            g
        }
    }
}

/// Poisoned acquisitions recovered so far (process-wide).
pub fn poisoned_count() -> u64 {
    LOCK_POISONED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Panic a holder thread on purpose so the mutex is poisoned.
    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
    }

    #[test]
    fn recovers_data_after_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u64));
        let before = poisoned_count();
        poison(&m);
        assert!(m.is_poisoned(), "holder panic must poison the mutex");
        // recovery hands back the guard with the pre-panic value
        {
            let mut g = lock_or_recover(&m);
            assert_eq!(*g, 7);
            *g = 8;
        }
        assert!(poisoned_count() > before, "recovery must be counted");
        // the poison flag is cleared: the next acquisition is clean
        assert!(!m.is_poisoned());
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn unpoisoned_path_does_not_count() {
        let m = Mutex::new(1i32);
        let before = poisoned_count();
        *lock_or_recover(&m) += 1;
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 3);
        assert_eq!(poisoned_count(), before);
    }

    #[test]
    fn timed_wait_wakes_without_a_notify() {
        let pair = (Mutex::new(()), Condvar::new());
        let g = lock_or_recover(&pair.0);
        let start = std::time::Instant::now();
        let _g = wait_timeout_or_recover(
            &pair.1,
            g,
            std::time::Duration::from_millis(10),
        );
        assert!(start.elapsed() >= std::time::Duration::from_millis(5));
    }

    #[test]
    fn wait_recovers_a_mutex_poisoned_while_sleeping() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let before = poisoned_count();
        let waiter = {
            let pair = pair.clone();
            std::thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut g = lock_or_recover(m);
                while !*g {
                    g = wait_or_recover(cv, g);
                }
                true
            })
        };
        // give the waiter time to block, then poison the mutex
        std::thread::sleep(std::time::Duration::from_millis(20));
        poison(&pair.0);
        // release the waiter through the recovered lock
        *lock_or_recover(&pair.0) = true;
        pair.1.notify_all();
        assert!(waiter.join().expect("waiter must survive the poison"));
        assert!(poisoned_count() > before);
    }
}
