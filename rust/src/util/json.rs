//! Minimal JSON substrate (no serde in the offline image).
//!
//! Covers exactly what the repo needs: parsing `artifacts/manifest.json`
//! and the coordinator's JSON-lines wire protocol, plus encoding metrics /
//! experiment rows.  Numbers come in two flavours: [`Json::Int`] holds
//! integer literals *exactly* (the wire protocol's request ids and seeds
//! are full-range u64 — going through f64 would silently round above
//! 2^53), and [`Json::Num`] holds everything with a fraction or exponent.
//! The two compare numerically equal when they denote the same value, so
//! callers never have to care which variant the parser produced.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Largest magnitude an f64 represents exactly as an integer (2^53);
/// beyond it, only [`Json::Int`] round-trips without loss.
const F64_EXACT: f64 = 9_007_199_254_740_992.0;

#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    /// integer literal, held exactly (covers the full u64 and i64 ranges)
    Int(i128),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            // Int and Num compare numerically: parsing "3" yields Int(3)
            // but programmatic construction often yields Num(3.0).  The
            // back-conversion guard keeps ints beyond f64 precision from
            // colliding with their rounded neighbours.
            (Json::Int(a), Json::Num(b)) | (Json::Num(b), Json::Int(a)) => {
                b.fract() == 0.0
                    && b.abs() < F64_EXACT
                    && *a == (*b as i128)
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Exact unsigned integer: `Int` within u64 range, or a `Num` whose
    /// value is a non-negative integer small enough (< 2^53) that the
    /// f64 representation is known to be exact.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(i) => u64::try_from(*i).ok(),
            Json::Num(n)
                if n.fract() == 0.0 && *n >= 0.0 && *n < F64_EXACT =>
            {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Exact signed integer (same exactness rules as [`Self::as_u64`]).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => i64::try_from(*i).ok(),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < F64_EXACT => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Consume an object into its field map; any other value has no
    /// fields and yields an empty map.  This is the panic-free way to
    /// stamp extra keys onto a value a constructor just built (the
    /// envelope codec's pattern) — total by construction, so the
    /// serving path needs no `let Json::Obj(..) else { unreachable!() }`
    /// destructures.
    pub fn into_obj(self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(m) => m,
            _ => BTreeMap::new(),
        }
    }

    // ------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Exact unsigned integer (ids, seeds, token values) — never loses
    /// precision, unlike routing a u64 through `num`.
    pub fn uint(n: u64) -> Json {
        Json::Int(n as i128)
    }

    /// Exact signed integer.
    pub fn int(n: i64) -> Json {
        Json::Int(n as i128)
    }

    // ---------------------------------------------------------- encoding
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 9e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----------------------------------------------------------- parsing
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut integral = true;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                self.i += 1;
            } else if matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                integral = false;
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| format!("bad number at byte {start}"))?;
        // pure-integer literals are held exactly (u64 ids/seeds beyond
        // 2^53 must not round through f64); absurdly long ones fall back
        // to f64 like any other out-of-range number
        if integral {
            if let Ok(i) = text.parse::<i128>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // surrogate pairs unsupported (never emitted by
                            // our own encoder or by python's json for the
                            // manifest content we parse)
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::str("x\"y\n")),
            ("c", Json::Arr(vec![Json::Null, Json::Bool(true)])),
        ]);
        let s = j.encode();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"x": [1, 2, {"y": "z"}], "w": -3.5e2}"#)
            .unwrap();
        assert_eq!(j.get("w").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(
            j.get("x").unwrap().idx(2).unwrap().get("y").unwrap().as_str(),
            Some("z")
        );
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::num(3.0).encode(), "3");
        assert_eq!(Json::num(3.25).encode(), "3.25");
    }

    #[test]
    fn exact_integers_beyond_f64_precision() {
        // u64::MAX and 2^53 + 1 are NOT representable in f64; the Int
        // variant must carry them exactly through parse -> encode
        for text in ["18446744073709551615", "9007199254740993"] {
            let j = Json::parse(text).unwrap();
            assert_eq!(j.encode(), text, "lossy round-trip of {text}");
            assert_eq!(j.as_u64(), Some(text.parse::<u64>().unwrap()));
        }
        assert_eq!(Json::uint(u64::MAX).encode(), "18446744073709551615");
        assert_eq!(Json::int(-42).encode(), "-42");
        assert_eq!(Json::parse("-42").unwrap().as_i64(), Some(-42));
        // negative or fractional values are not unsigned integers
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        // a Num beyond the f64-exact range is refused rather than
        // silently rounded
        assert_eq!(Json::Num(1e18).as_u64(), None);
    }

    #[test]
    fn int_and_num_compare_numerically() {
        assert_eq!(Json::parse("3").unwrap(), Json::num(3.0));
        assert_eq!(Json::num(3.0), Json::parse("3").unwrap());
        assert_ne!(Json::parse("3").unwrap(), Json::num(3.5));
        assert_eq!(Json::parse("[1,2]").unwrap(), {
            Json::Arr(vec![Json::num(1.0), Json::num(2.0)])
        });
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(j.as_str(), Some("aéb"));
    }

    #[test]
    fn roundtrip_property_random_numbers() {
        let mut r = crate::util::prng::Prng::new(17);
        for _ in 0..200 {
            let n = (r.gaussian() * 1e6).round() / 64.0;
            let s = Json::Num(n).encode();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert!((back - n).abs() < 1e-9, "{n} vs {back}");
        }
    }
}
