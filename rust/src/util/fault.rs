//! Deterministic fault injection for chaos testing the serving path.
//!
//! A fixed set of named injection points is compiled into the stack
//! (worker step loop, artifact-cache bind, journal append, connection
//! writer).  Each point calls [`check`] once per traversal; when a
//! fault is armed for that point's Nth hit, `check` returns the armed
//! [`FaultAction`] exactly once and the caller performs it (panic,
//! typed failure, dropped connection, or an injected latency spike).
//! Unarmed, the whole registry is one relaxed atomic load — zero cost
//! on the hot path.
//!
//! Faults are armed from a spec string (`--faults` flag or the
//! `REPRO_FAULTS` env var):
//!
//! ```text
//! point@N:kind[=ARG][,point@N:kind...]
//! ```
//!
//! * `point` — one of [`POINTS`]: `worker_panic`, `slow_step`,
//!   `cache_mmap`, `journal_write`, `conn_drop`;
//! * `N` — the 0-based hit index at which the fault fires (the point's
//!   hit counter is global across threads, so schedules are
//!   deterministic under a deterministic workload);
//! * `kind` — `panic`, `fail`, `drop`, or `sleep_ms=MS`.
//!
//! Example: `worker_panic@3:panic,slow_step@0:sleep_ms=250` panics the
//! worker on its 4th device step and stretches the very first step by
//! 250 ms.  Every firing is counted; the engine surfaces the counts as
//! `faults_injected_<point>` metrics keys.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::sync::lock_or_recover;

/// Every injection point compiled into the serving path.  `check` only
/// accepts these names, so a typo in a spec is a parse error, not a
/// fault that silently never fires.
pub const POINTS: [&str; 5] = [
    "worker_panic",
    "slow_step",
    "cache_mmap",
    "journal_write",
    "conn_drop",
];

/// What an armed fault does when it fires.  The injection *site*
/// performs the action (only it knows how to panic safely, fail typed,
/// or drop its connection); the registry just says which.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// unwind the calling thread (worker-panic chaos)
    Panic,
    /// return the site's typed failure (mmap error, journal IO error)
    Fail,
    /// sever the site's connection mid-frame
    Drop,
    /// stretch the current step by this many milliseconds
    SleepMs(u64),
}

struct Arm {
    point: usize,
    /// fire on the hit whose pre-increment counter equals this
    at: u64,
    action: FaultAction,
    fired: bool,
}

#[derive(Default)]
struct Inner {
    arms: Vec<Arm>,
    /// per-point traversal counters (index into [`POINTS`])
    hits: [u64; POINTS.len()],
    /// per-point fired counters — the `faults_injected_*` lane
    fired: [u64; POINTS.len()],
}

/// Fast-path gate: false ⇒ `check` is one relaxed load and a branch.
static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<Inner> {
    static REG: OnceLock<Mutex<Inner>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Inner::default()))
}

fn point_index(name: &str) -> Option<usize> {
    POINTS.iter().position(|p| *p == name)
}

/// Parse one spec string into arms.  Errors name the offending clause
/// so a mistyped schedule fails loudly at startup, never silently.
fn parse_spec(spec: &str) -> Result<Vec<Arm>, String> {
    let mut arms = Vec::new();
    for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
        let (point_at, kind) = clause
            .split_once(':')
            .ok_or_else(|| format!("fault `{clause}`: missing `:kind`"))?;
        let (point, at) = point_at
            .split_once('@')
            .ok_or_else(|| format!("fault `{clause}`: missing `@N`"))?;
        let point = point_index(point.trim()).ok_or_else(|| {
            format!(
                "fault `{clause}`: unknown point `{}` (expected one of {})",
                point.trim(),
                POINTS.join(", ")
            )
        })?;
        let at: u64 = at.trim().parse().map_err(|_| {
            format!("fault `{clause}`: hit index `{}` is not a u64", at.trim())
        })?;
        let action = match kind.trim() {
            "panic" => FaultAction::Panic,
            "fail" => FaultAction::Fail,
            "drop" => FaultAction::Drop,
            k => {
                let ms = k
                    .strip_prefix("sleep_ms=")
                    .and_then(|v| v.parse::<u64>().ok())
                    .ok_or_else(|| {
                        format!(
                            "fault `{clause}`: unknown kind `{k}` \
                             (expected panic|fail|drop|sleep_ms=MS)"
                        )
                    })?;
                FaultAction::SleepMs(ms)
            }
        };
        arms.push(Arm { point, at, action, fired: false });
    }
    Ok(arms)
}

/// Arm the registry from a spec string, replacing any previous
/// schedule and resetting all hit counters.  Returns the number of
/// arms installed.  An empty spec disarms (same as [`clear`]).
pub fn install(spec: &str) -> Result<usize, String> {
    let arms = parse_spec(spec)?;
    let n = arms.len();
    let mut inner = lock_or_recover(registry());
    inner.arms = arms;
    inner.hits = [0; POINTS.len()];
    inner.fired = [0; POINTS.len()];
    ARMED.store(n > 0, Ordering::Release);
    Ok(n)
}

/// Arm from the `REPRO_FAULTS` env var, if set.  Returns the number of
/// arms installed (0 when unset).
pub fn install_from_env() -> Result<usize, String> {
    match std::env::var("REPRO_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => install(&spec),
        _ => Ok(0),
    }
}

/// Disarm every fault and reset the counters (fired totals included).
pub fn clear() {
    let mut inner = lock_or_recover(registry());
    inner.arms.clear();
    inner.hits = [0; POINTS.len()];
    inner.fired = [0; POINTS.len()];
    ARMED.store(false, Ordering::Release);
}

/// One traversal of the named injection point.  Returns the armed
/// action exactly when this traversal is the hit a schedule names;
/// `None` otherwise — and with nothing armed, this is a single relaxed
/// atomic load.  Unknown point names count nothing and never fire
/// (sites pass literals from [`POINTS`], so this is defensive only).
pub fn check(point: &str) -> Option<FaultAction> {
    if !ARMED.load(Ordering::Acquire) {
        return None;
    }
    let pi = point_index(point)?;
    let mut inner = lock_or_recover(registry());
    let hit = inner.hits[pi];
    inner.hits[pi] += 1;
    let action = inner
        .arms
        .iter_mut()
        .find(|a| a.point == pi && !a.fired && a.at == hit)
        .map(|a| {
            a.fired = true;
            a.action
        });
    if action.is_some() {
        inner.fired[pi] += 1;
    }
    action
}

/// Fired counts per point, only for points that fired at least once —
/// the engine's `faults_injected_<point>` metrics lane.
pub fn fired_counts() -> Vec<(&'static str, u64)> {
    if !ARMED.load(Ordering::Acquire) {
        return Vec::new();
    }
    let inner = lock_or_recover(registry());
    POINTS
        .iter()
        .zip(inner.fired.iter())
        .filter(|(_, &n)| n > 0)
        .map(|(&p, &n)| (p, n))
        .collect()
}

/// Tests sharing the process-global registry must serialize: hold this
/// guard for the whole armed window.  (Integration tests run in their
/// own processes; this is for unit tests inside the library crate.)
#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    lock_or_recover(&GATE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_is_none() {
        let _g = test_serial();
        clear();
        assert_eq!(check("worker_panic"), None);
        assert!(fired_counts().is_empty());
    }

    #[test]
    fn fires_on_exact_hit_index_once() {
        let _g = test_serial();
        install("slow_step@2:sleep_ms=5").unwrap();
        assert_eq!(check("slow_step"), None); // hit 0
        assert_eq!(check("slow_step"), None); // hit 1
        assert_eq!(check("slow_step"), Some(FaultAction::SleepMs(5)));
        assert_eq!(check("slow_step"), None); // fired arms stay fired
        assert_eq!(fired_counts(), vec![("slow_step", 1)]);
        clear();
    }

    #[test]
    fn points_count_independently() {
        let _g = test_serial();
        install("worker_panic@0:panic,cache_mmap@1:fail").unwrap();
        assert_eq!(check("cache_mmap"), None); // cache hit 0
        assert_eq!(check("worker_panic"), Some(FaultAction::Panic));
        assert_eq!(check("cache_mmap"), Some(FaultAction::Fail));
        clear();
    }

    #[test]
    fn spec_errors_are_typed() {
        let _g = test_serial();
        assert!(install("nonsense").is_err());
        assert!(install("bogus_point@0:panic").is_err());
        assert!(install("slow_step@x:panic").is_err());
        assert!(install("slow_step@0:explode").is_err());
        assert!(install("slow_step@0:sleep_ms=abc").is_err());
        // a failed install never leaves stale arms behind
        assert_eq!(check("slow_step"), None);
    }

    #[test]
    fn install_replaces_previous_schedule() {
        let _g = test_serial();
        install("conn_drop@0:drop").unwrap();
        install("journal_write@0:fail").unwrap();
        assert_eq!(check("conn_drop"), None);
        assert_eq!(check("journal_write"), Some(FaultAction::Fail));
        install("").unwrap();
        assert_eq!(check("journal_write"), None);
        clear();
    }
}
