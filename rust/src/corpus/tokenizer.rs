//! Word-level tokenizer over the static vocabulary.
//!
//! The synthetic corpus is generated *as token ids* (the grammar samples
//! words directly), so the tokenizer's main jobs are decoding samples for
//! human inspection / WER / GPT-Score-lite, and encoding prompt text for
//! the serving API.

use std::collections::BTreeMap;

use super::words;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    words: Vec<&'static str>,
    index: BTreeMap<&'static str, i32>,
    /// model vocabulary size (>= words.len(); ids beyond the word list
    /// decode to <unk-N> placeholders)
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Tokenizer {
        let words = words::vocabulary();
        assert!(words.len() <= vocab_size);
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (*w, i as i32))
            .collect();
        Tokenizer {
            words,
            index,
            vocab_size,
        }
    }

    /// Number of *real* words (ids below this decode to text).
    pub fn n_words(&self) -> usize {
        self.words.len()
    }

    pub fn id(&self, word: &str) -> i32 {
        self.index.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.words
            .get(id as usize)
            .copied()
            .unwrap_or("<oov>")
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for (i, &id) in ids.iter().enumerate() {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(self.word(id));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known_words() {
        let t = Tokenizer::new(512);
        let text = "the quick fox jumps over the lazy dog .";
        let ids = t.encode(text);
        assert_eq!(t.decode(&ids), text);
        assert!(ids.iter().all(|&i| i != UNK));
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = Tokenizer::new(512);
        assert_eq!(t.encode("qwertyuiop"), vec![UNK]);
    }

    #[test]
    fn out_of_vocab_ids_decode_safely() {
        let t = Tokenizer::new(512);
        assert_eq!(t.word(511), "<oov>");
        assert_eq!(t.word(UNK), "<unk>");
    }

    #[test]
    fn encode_decode_identity_property() {
        // property: decode(encode(s)) == s for any sentence over the vocab
        let t = Tokenizer::new(512);
        let mut r = crate::util::prng::Prng::new(21);
        for _ in 0..50 {
            let n = 1 + r.below(30);
            let sent: Vec<&str> =
                (0..n).map(|_| t.words[r.below(t.n_words())]).collect();
            let text = sent.join(" ");
            assert_eq!(t.decode(&t.encode(&text)), text);
        }
    }
}
