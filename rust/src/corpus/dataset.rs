//! Dataset plumbing: training batches, validation prompts, and the
//! masking strategies from CDCD Appendix A.1 (MLM / prefix / span) that
//! the Table-4..7 ablation sweeps.

use super::grammar::Grammar;
use crate::util::prng::Prng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Masking {
    /// noise random positions (like masked-LM training)
    Mlm,
    /// keep a random-length prefix intact, noise the continuation
    Prefix,
    /// split into k spans, noise each span w.p. 0.5 (Strudel et al. 2023)
    Span,
}

impl Masking {
    pub fn parse(s: &str) -> Option<Masking> {
        match s {
            "mlm" => Some(Masking::Mlm),
            "prefix" => Some(Masking::Prefix),
            "span" => Some(Masking::Span),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Masking::Mlm => "mlm",
            Masking::Prefix => "prefix",
            Masking::Span => "span",
        }
    }
}

/// Maximum number of spans for span masking (k_max = 9 in the paper).
pub const SPAN_K_MAX: usize = 9;

/// One training batch: row-major `[batch, seq_len]` tokens and the noise
/// mask (1.0 = position is noised; CE is computed only there).
pub struct Batch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq_len: usize,
}

pub struct Dataset {
    grammar: Grammar,
    pub seq_len: usize,
}

impl Dataset {
    pub fn new(vocab_size: usize, seq_len: usize) -> Dataset {
        Dataset {
            grammar: Grammar::new(vocab_size),
            seq_len,
        }
    }

    pub fn grammar(&self) -> &Grammar {
        &self.grammar
    }

    /// Sample a noise mask for one sequence according to the strategy.
    pub fn sample_mask(
        &self,
        rng: &mut Prng,
        strategy: Masking,
        out: &mut [f32],
    ) {
        let l = out.len();
        match strategy {
            Masking::Mlm => {
                // noise each position independently; rate ~ U[0.3, 1.0]
                // so the model sees both light and full corruption
                let rate = 0.3 + 0.7 * rng.uniform();
                let mut any = false;
                for m in out.iter_mut() {
                    let bit = rng.uniform() < rate;
                    *m = bit as u8 as f32;
                    any |= bit;
                }
                if !any {
                    out[rng.below(l)] = 1.0;
                }
            }
            Masking::Prefix => {
                // keep a prefix of random length [0, L-1] intact
                let keep = rng.below(l);
                for (i, m) in out.iter_mut().enumerate() {
                    *m = (i >= keep) as u8 as f32;
                }
            }
            Masking::Span => {
                let k = 1 + rng.below(SPAN_K_MAX);
                // choose k-1 cut indices -> k spans; each noised w.p. 0.5
                let mut cuts: Vec<usize> =
                    (0..k - 1).map(|_| 1 + rng.below(l - 1)).collect();
                cuts.sort_unstable();
                cuts.dedup();
                cuts.push(l);
                let mut start = 0usize;
                let mut any = false;
                for &end in &cuts {
                    let noised = rng.uniform() < 0.5;
                    for m in &mut out[start..end] {
                        *m = noised as u8 as f32;
                    }
                    any |= noised && end > start;
                    start = end;
                }
                if !any {
                    // degenerate all-clean draw: force one noised span
                    let s = rng.below(l);
                    for m in &mut out[s..l] {
                        *m = 1.0;
                    }
                }
            }
        }
    }

    /// A full training batch with per-sequence masks.
    pub fn train_batch(
        &self,
        rng: &mut Prng,
        batch: usize,
        strategy: Masking,
    ) -> Batch {
        let l = self.seq_len;
        let mut tokens = Vec::with_capacity(batch * l);
        let mut mask = vec![0.0f32; batch * l];
        for b in 0..batch {
            tokens.extend(self.grammar.sequence(rng, l));
            self.sample_mask(rng, strategy, &mut mask[b * l..(b + 1) * l]);
        }
        Batch {
            tokens,
            mask,
            batch,
            seq_len: l,
        }
    }

    /// Deterministic validation prompts: `n` sequences, of which the first
    /// `prefix_len` tokens act as the conditioning prefix (Prefix-32 task).
    pub fn val_prompts(&self, seed: u64, n: usize) -> Vec<Vec<i32>> {
        let mut rng = Prng::new(seed).fork("validation");
        (0..n).map(|_| self.grammar.sequence(&mut rng, self.seq_len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Dataset {
        Dataset::new(512, 64)
    }

    #[test]
    fn batch_shapes() {
        let d = ds();
        let mut r = Prng::new(1);
        let b = d.train_batch(&mut r, 4, Masking::Mlm);
        assert_eq!(b.tokens.len(), 4 * 64);
        assert_eq!(b.mask.len(), 4 * 64);
        assert!(b.mask.iter().all(|&m| m == 0.0 || m == 1.0));
    }

    #[test]
    fn every_mask_strategy_noises_something() {
        let d = ds();
        let mut r = Prng::new(2);
        for strat in [Masking::Mlm, Masking::Prefix, Masking::Span] {
            for _ in 0..100 {
                let mut m = vec![0.0f32; 64];
                d.sample_mask(&mut r, strat, &mut m);
                assert!(
                    m.iter().any(|&x| x == 1.0),
                    "{strat:?} produced an all-clean mask"
                );
            }
        }
    }

    #[test]
    fn prefix_mask_is_contiguous_suffix() {
        let d = ds();
        let mut r = Prng::new(3);
        for _ in 0..100 {
            let mut m = vec![0.0f32; 64];
            d.sample_mask(&mut r, Masking::Prefix, &mut m);
            // once masking starts it never stops
            let first = m.iter().position(|&x| x == 1.0).unwrap();
            assert!(m[first..].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn span_mask_has_bounded_span_count() {
        let d = ds();
        let mut r = Prng::new(4);
        for _ in 0..100 {
            let mut m = vec![0.0f32; 64];
            d.sample_mask(&mut r, Masking::Span, &mut m);
            // count transitions; spans <= k_max means transitions bounded
            let transitions = m.windows(2).filter(|w| w[0] != w[1]).count();
            assert!(transitions <= 2 * SPAN_K_MAX);
        }
    }

    #[test]
    fn val_prompts_deterministic() {
        let d = ds();
        assert_eq!(d.val_prompts(9, 5), d.val_prompts(9, 5));
        assert_ne!(d.val_prompts(9, 5), d.val_prompts(10, 5));
    }

    #[test]
    fn masking_parse_roundtrip() {
        for s in [Masking::Mlm, Masking::Prefix, Masking::Span] {
            assert_eq!(Masking::parse(s.name()), Some(s));
        }
        assert_eq!(Masking::parse("bogus"), None);
    }
}
