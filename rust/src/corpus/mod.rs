//! Synthetic-corpus substrate standing in for C4 (DESIGN.md §8): a
//! stochastic grammar with Zipf-weighted word choice, a word-level
//! tokenizer, and the dataset/masking plumbing for training + evaluation.

pub mod dataset;
pub mod grammar;
pub mod tokenizer;
pub mod words;
