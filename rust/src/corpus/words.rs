//! Static word inventory for the synthetic corpus (stands in for C4,
//! DESIGN.md §8).  Categories feed the stochastic grammar; within each
//! category, sampling is Zipf-weighted so the corpus reproduces the
//! natural-language rank-frequency shape the paper's Zipf-coefficient
//! metric measures (data row in Table 3 reports ~0.9).

pub const DETERMINERS: &[&str] = &["the", "a", "this", "that", "every", "some"];

pub const ADJECTIVES: &[&str] = &[
    "quick", "lazy", "bright", "dark", "small", "large", "old", "young",
    "red", "blue", "green", "quiet", "loud", "happy", "sad", "cold",
    "warm", "early", "late", "long", "short", "high", "low", "deep",
    "shallow", "rich", "poor", "clean", "dirty", "fresh", "ancient",
    "modern", "simple", "complex", "gentle", "fierce", "hollow", "solid",
    "distant", "nearby", "silver", "golden", "wooden", "iron", "broken",
    "silent", "curious", "famous", "hidden", "open",
];

pub const NOUNS: &[&str] = &[
    "fox", "dog", "cat", "bird", "fish", "horse", "river", "mountain",
    "forest", "valley", "city", "village", "house", "garden", "road",
    "bridge", "tower", "castle", "market", "harbor", "ship", "train",
    "letter", "book", "story", "song", "painting", "window", "door",
    "table", "chair", "lamp", "clock", "mirror", "key", "map", "coin",
    "stone", "tree", "flower", "leaf", "branch", "root", "seed", "cloud",
    "storm", "rain", "snow", "wind", "fire", "shadow", "light", "morning",
    "evening", "night", "winter", "summer", "spring", "autumn", "child",
    "farmer", "sailor", "teacher", "doctor", "baker", "hunter", "writer",
    "painter", "soldier", "merchant", "king", "queen", "friend", "neighbor",
    "stranger", "traveler", "guard", "thief", "crowd", "family", "island",
    "desert", "ocean", "lake", "field", "meadow", "path", "wall", "roof",
    "cellar", "attic", "kitchen", "journey", "secret", "promise", "dream",
    "memory", "voice", "silence", "answer", "question",
];

pub const VERBS: &[&str] = &[
    "jumps", "runs", "walks", "flies", "swims", "climbs", "falls", "rises",
    "opens", "closes", "builds", "breaks", "carries", "drops", "finds",
    "loses", "watches", "follows", "leads", "crosses", "enters", "leaves",
    "reaches", "touches", "holds", "throws", "catches", "pulls", "pushes",
    "writes", "reads", "sings", "paints", "draws", "tells", "hears",
    "sees", "knows", "remembers", "forgets", "believes", "hopes", "fears",
    "loves", "hates", "wants", "needs", "makes", "takes", "gives",
    "brings", "sends", "keeps", "hides", "shows", "burns", "freezes",
    "grows", "shrinks", "waits",
];

pub const ADVERBS: &[&str] = &[
    "quickly", "slowly", "quietly", "loudly", "carefully", "suddenly",
    "gently", "fiercely", "often", "rarely", "always", "never", "soon",
    "swiftly", "eagerly", "far", "closely", "again", "once", "twice", "together",
    "alone", "everywhere", "nowhere", "somewhere", "yesterday", "today",
    "tomorrow", "forever", "almost",
];

pub const PREPOSITIONS: &[&str] = &[
    "over", "under", "through", "across", "around", "behind", "beside",
    "between", "beyond", "inside", "outside", "toward", "against", "near",
    "past",
];

pub const CONJUNCTIONS: &[&str] = &["and", "but", "while", "because", "until"];

pub const PRONOUNS: &[&str] = &["it", "he", "she", "they", "we"];

pub const NAMES: &[&str] = &[
    "anna", "boris", "clara", "daniel", "elena", "felix", "greta", "henry",
    "irene", "jonas", "karin", "leo", "maria", "nils", "olga", "peter",
    "rosa", "stefan", "tanya", "viktor",
];

pub const PUNCT: &[&str] = &[".", ",", ";", "?"];

/// Special tokens, always the first vocabulary entries.
pub const SPECIALS: &[&str] = &["<pad>", "<unk>", "<bos>"];

/// Full vocabulary in deterministic order (specials first).
pub fn vocabulary() -> Vec<&'static str> {
    let mut v: Vec<&'static str> = Vec::new();
    v.extend_from_slice(SPECIALS);
    v.extend_from_slice(PUNCT);
    v.extend_from_slice(DETERMINERS);
    v.extend_from_slice(PRONOUNS);
    v.extend_from_slice(CONJUNCTIONS);
    v.extend_from_slice(PREPOSITIONS);
    v.extend_from_slice(ADVERBS);
    v.extend_from_slice(ADJECTIVES);
    v.extend_from_slice(VERBS);
    v.extend_from_slice(NOUNS);
    v.extend_from_slice(NAMES);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn vocabulary_is_unique_and_fits_512() {
        let v = vocabulary();
        let set: HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), v.len(), "duplicate words in the inventory");
        assert!(v.len() <= 512, "vocabulary {} exceeds model vocab", v.len());
        assert!(v.len() >= 250, "vocabulary too small to be interesting");
    }

    #[test]
    fn specials_first() {
        let v = vocabulary();
        assert_eq!(&v[..3], SPECIALS);
    }
}
