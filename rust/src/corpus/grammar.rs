//! Stochastic grammar: the synthetic-corpus generator standing in for C4
//! (DESIGN.md §8).
//!
//! A weighted CFG over the static word inventory produces sentences with
//! real constituent structure (NP/VP/PP, agreement-free but syntactically
//! regular), so the DLMs have *learnable* sequential structure — the
//! property the paper's convergence dynamics depend on.  Within each
//! part-of-speech category, word choice is Zipf(s)-weighted, giving the
//! corpus the rank-frequency profile that makes the Zipf-coefficient
//! metric meaningful (paper Table 3 reports ~0.9 for C4 data).

use super::tokenizer::Tokenizer;
use super::words;
use crate::util::prng::Prng;

/// Zipf exponent for within-category word choice.
const ZIPF_S: f64 = 1.05;

pub struct Grammar {
    tok: Tokenizer,
    det: Cat,
    adj: Cat,
    noun: Cat,
    verb: Cat,
    adv: Cat,
    prep: Cat,
    conj: Cat,
    pron: Cat,
    name: Cat,
}

struct Cat {
    ids: Vec<i32>,
    weights: Vec<f64>,
}

impl Cat {
    fn new(tok: &Tokenizer, words: &[&str]) -> Cat {
        let ids = words.iter().map(|w| tok.id(w)).collect();
        let weights = (0..words.len())
            .map(|r| 1.0 / ((r + 1) as f64).powf(ZIPF_S))
            .collect();
        Cat { ids, weights }
    }

    fn sample(&self, rng: &mut Prng) -> i32 {
        self.ids[rng.weighted(&self.weights)]
    }
}

impl Grammar {
    pub fn new(vocab_size: usize) -> Grammar {
        let tok = Tokenizer::new(vocab_size);
        Grammar {
            det: Cat::new(&tok, words::DETERMINERS),
            adj: Cat::new(&tok, words::ADJECTIVES),
            noun: Cat::new(&tok, words::NOUNS),
            verb: Cat::new(&tok, words::VERBS),
            adv: Cat::new(&tok, words::ADVERBS),
            prep: Cat::new(&tok, words::PREPOSITIONS),
            conj: Cat::new(&tok, words::CONJUNCTIONS),
            pron: Cat::new(&tok, words::PRONOUNS),
            name: Cat::new(&tok, words::NAMES),
            tok,
        }
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// NP -> Det (Adj){0..2} Noun | Name | Pron
    fn np(&self, rng: &mut Prng, out: &mut Vec<i32>) {
        match rng.weighted(&[0.62, 0.2, 0.18]) {
            0 => {
                out.push(self.det.sample(rng));
                let n_adj = rng.weighted(&[0.5, 0.38, 0.12]);
                for _ in 0..n_adj {
                    out.push(self.adj.sample(rng));
                }
                out.push(self.noun.sample(rng));
            }
            1 => out.push(self.name.sample(rng)),
            _ => out.push(self.pron.sample(rng)),
        }
    }

    /// PP -> Prep NP
    fn pp(&self, rng: &mut Prng, out: &mut Vec<i32>) {
        out.push(self.prep.sample(rng));
        self.np(rng, out);
    }

    /// VP -> Verb (NP | PP | Adv | NP PP)
    fn vp(&self, rng: &mut Prng, out: &mut Vec<i32>) {
        out.push(self.verb.sample(rng));
        match rng.weighted(&[0.35, 0.3, 0.15, 0.2]) {
            0 => self.np(rng, out),
            1 => self.pp(rng, out),
            2 => out.push(self.adv.sample(rng)),
            _ => {
                self.np(rng, out);
                self.pp(rng, out);
            }
        }
    }

    /// S -> NP VP (Conj NP VP)? Punct
    pub fn sentence(&self, rng: &mut Prng, out: &mut Vec<i32>) {
        self.np(rng, out);
        self.vp(rng, out);
        if rng.uniform() < 0.25 {
            out.push(self.conj.sample(rng));
            self.np(rng, out);
            self.vp(rng, out);
        }
        let punct = if rng.uniform() < 0.85 { "." } else { "," };
        out.push(self.tok.id(punct));
    }

    /// A continuous token stream of exactly `len` tokens (sentences
    /// truncated at the boundary, like C4's packed sequences).
    pub fn sequence(&self, rng: &mut Prng, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len + 16);
        while out.len() < len {
            self.sentence(rng, &mut out);
        }
        out.truncate(len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_has_exact_length_and_valid_ids() {
        let g = Grammar::new(512);
        let mut r = Prng::new(1);
        for len in [16usize, 64, 256] {
            let s = g.sequence(&mut r, len);
            assert_eq!(s.len(), len);
            let nw = g.tokenizer().n_words() as i32;
            assert!(s.iter().all(|&t| t >= 0 && t < nw));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = Grammar::new(512);
        let a = g.sequence(&mut Prng::new(7), 64);
        let b = g.sequence(&mut Prng::new(7), 64);
        assert_eq!(a, b);
    }

    #[test]
    fn sentences_end_with_punctuation() {
        let g = Grammar::new(512);
        let mut r = Prng::new(3);
        let mut s = Vec::new();
        g.sentence(&mut r, &mut s);
        let last = g.tokenizer().word(*s.last().unwrap());
        assert!(last == "." || last == ",");
        assert!(s.len() >= 3, "sentence too short: {s:?}");
    }

    #[test]
    fn corpus_is_zipf_like() {
        // rank-frequency slope of the generated corpus should be in the
        // "natural language" band the paper's Zipf metric targets
        let g = Grammar::new(512);
        let mut r = Prng::new(11);
        let mut counts = vec![0usize; 512];
        for _ in 0..200 {
            for t in g.sequence(&mut r, 64) {
                counts[t as usize] += 1;
            }
        }
        let mut freqs: Vec<f64> = counts
            .into_iter()
            .filter(|&c| c > 0)
            .map(|c| c as f64)
            .collect();
        freqs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // linear regression of log-freq on log-rank
        let n = freqs.len().min(200);
        let xs: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).ln()).collect();
        let ys: Vec<f64> = freqs[..n].iter().map(|f| f.ln()).collect();
        let mx = xs.iter().sum::<f64>() / n as f64;
        let my = ys.iter().sum::<f64>() / n as f64;
        let sxy: f64 =
            xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
        let slope = sxy / sxx;
        assert!(
            (-2.0..=-0.5).contains(&slope),
            "zipf slope {slope} outside natural-language band"
        );
    }

    #[test]
    fn vocabulary_coverage() {
        // over many samples, a large fraction of the vocabulary appears
        let g = Grammar::new(512);
        let mut r = Prng::new(13);
        let mut seen = vec![false; 512];
        for _ in 0..500 {
            for t in g.sequence(&mut r, 64) {
                seen[t as usize] = true;
            }
        }
        let used = seen.iter().filter(|&&b| b).count();
        assert!(
            used as f64 > 0.6 * g.tokenizer().n_words() as f64,
            "only {used} words used"
        );
    }
}
