//! Open, process-wide registry of sampler kernels.
//!
//! The `Family` enum stays the ergonomic handle for the three paper
//! families, but the *wire* no longer closes over it: every kernel —
//! built-in or registered at runtime — is addressed by a [`FamilyId`],
//! a dense handle resolved from the kernel's canonical name.  The
//! serving stack (requests, routing tables, metrics lanes, worker
//! specs) speaks `FamilyId` exclusively, so an out-of-tree
//! [`FamilyKernel`] registered through [`register`] is servable
//! end-to-end — CLI `--fleet`, wire `"family"` field, per-family
//! metrics — without touching the enum.
//!
//! Registration is a process-lifetime act: kernels are leaked into
//! `'static` storage and ids are never reused.  The registry is seeded
//! with the built-ins at indices matching `Family::index()`, so
//! `FamilyId::from(Family)` is a constant-time conversion.

use std::sync::{OnceLock, RwLock};

use super::kernel::{DdlmKernel, Family, FamilyKernel, PlaidKernel, SsdKernel};

/// Dense handle for a registered sampler kernel — the serving stack's
/// family currency (wire field `family`, routing tables, metrics lanes).
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub struct FamilyId(u16);

fn kernels() -> &'static RwLock<Vec<&'static dyn FamilyKernel>> {
    static REG: OnceLock<RwLock<Vec<&'static dyn FamilyKernel>>> =
        OnceLock::new();
    REG.get_or_init(|| RwLock::new(vec![&DdlmKernel, &SsdKernel, &PlaidKernel]))
}

impl FamilyId {
    /// Dense index (stable for the process lifetime; built-ins occupy
    /// `0..Family::COUNT` in `Family::index()` order).
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// The kernel this id resolves to.
    pub fn kernel(self) -> &'static dyn FamilyKernel {
        kernels().read().unwrap()[self.0 as usize]
    }

    /// Canonical lowercase name (wire value, metrics suffix).
    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    /// The built-in enum variant, when this id names one (runtime
    /// registrations return `None`).
    pub fn builtin(self) -> Option<Family> {
        Family::all().into_iter().find(|f| f.index() == self.index())
    }
}

impl From<Family> for FamilyId {
    fn from(f: Family) -> FamilyId {
        FamilyId(f.index() as u16)
    }
}

impl PartialEq<Family> for FamilyId {
    fn eq(&self, other: &Family) -> bool {
        self.index() == other.index()
    }
}

impl PartialEq<FamilyId> for Family {
    fn eq(&self, other: &FamilyId) -> bool {
        self.index() == other.index()
    }
}

impl std::fmt::Display for FamilyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed registration failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// another kernel already owns this name (names key wire routing,
    /// so they must be unique)
    DuplicateName(String),
    /// the name cannot travel everywhere a family name must: it is
    /// empty, or contains a character outside `[a-z0-9_-]` (`:` and
    /// `,` delimit CLI `--fleet`/`--schedule` specs, and names suffix
    /// metrics keys)
    InvalidName(String),
    /// the dense-id space is exhausted (u16 — far beyond any real use)
    Full,
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::DuplicateName(n) => {
                write!(f, "family {n:?} is already registered")
            }
            RegistryError::InvalidName(n) => write!(
                f,
                "family name {n:?} is not servable (want non-empty \
                 [a-z0-9_-])"
            ),
            RegistryError::Full => f.write_str("family registry is full"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// Register an out-of-tree kernel; its name becomes resolvable on the
/// wire and the CLI, and the returned id is valid for worker specs,
/// requests and metrics lanes.  The kernel is leaked into `'static`
/// storage (registration is for the process lifetime).  Names are
/// validated here — the one choke point — so every downstream consumer
/// (CLI spec parsing, metrics key suffixes, wire values) can trust
/// them.
pub fn register(
    kernel: Box<dyn FamilyKernel>,
) -> Result<FamilyId, RegistryError> {
    let name = kernel.name();
    if name.is_empty()
        || !name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-')
    {
        return Err(RegistryError::InvalidName(name.to_string()));
    }
    let mut reg = kernels().write().unwrap();
    if reg.iter().any(|k| k.name() == name) {
        return Err(RegistryError::DuplicateName(name.to_string()));
    }
    if reg.len() > u16::MAX as usize {
        return Err(RegistryError::Full);
    }
    let id = FamilyId(reg.len() as u16);
    reg.push(Box::leak(kernel));
    Ok(id)
}

/// A ready-made out-of-tree kernel: serves `base`'s compiled artifacts
/// and checkpoints under a new wire name, delegating every behaviour.
/// Registering one is the smallest possible runtime family
/// (`registry::register(Box::new(AliasKernel::new("ddlm-canary",
/// &DdlmKernel)))`); for a kernel that varies host-side behaviour,
/// implement [`FamilyKernel`] directly and point `artifact_prefix()`
/// at the family whose device artifacts it reuses.
pub struct AliasKernel {
    name: &'static str,
    base: &'static dyn FamilyKernel,
}

impl AliasKernel {
    pub fn new(
        name: &'static str,
        base: &'static dyn FamilyKernel,
    ) -> AliasKernel {
        AliasKernel { name, base }
    }
}

impl FamilyKernel for AliasKernel {
    fn name(&self) -> &'static str {
        self.name
    }
    fn artifact_prefix(&self) -> &'static str {
        self.base.artifact_prefix()
    }
    fn state_row(&self, l: usize, v: usize, d: usize) -> usize {
        self.base.state_row(l, v, d)
    }
    fn times(&self, n_steps: usize, t_max: f32, t_min: f32) -> Vec<f32> {
        self.base.times(n_steps, t_max, t_min)
    }
    fn init_sigma(&self, times: &[f32]) -> f32 {
        self.base.init_sigma(times)
    }
    fn init_state(
        &self,
        x: &mut [f32],
        sigma: f32,
        simplex_k: f32,
        rng: &mut crate::util::prng::Prng,
    ) {
        self.base.init_state(x, sigma, simplex_k, rng);
    }
    fn time_input(&self) -> &'static str {
        self.base.time_input()
    }
    fn needs_z(&self) -> bool {
        self.base.needs_z()
    }
    fn idle_times(&self) -> (f32, f32) {
        self.base.idle_times()
    }
    fn supports_device_residency(&self) -> bool {
        self.base.supports_device_residency()
    }
    fn supports_token_halting(&self) -> bool {
        self.base.supports_token_halting()
    }
    fn clamp_token(
        &self,
        dst: &mut [f32],
        tok: usize,
        emb_row: &[f32],
        simplex_k: f32,
    ) {
        self.base.clamp_token(dst, tok, emb_row, simplex_k);
    }
    fn parse_stats(
        &self,
        slot: usize,
        out: &super::kernel::StepOutputs<'_>,
    ) -> crate::halting::StepStats {
        self.base.parse_stats(slot, out)
    }
}

/// Resolve a family name — built-in or registered — to its id.  This is
/// the wire boundary's lookup; `Family::parse` only knows the enum.
pub fn resolve(name: &str) -> Option<FamilyId> {
    kernels()
        .read()
        .unwrap()
        .iter()
        .position(|k| k.name() == name)
        .map(|i| FamilyId(i as u16))
}

/// Number of registered kernels (>= `Family::COUNT`).
pub fn count() -> usize {
    kernels().read().unwrap().len()
}

/// Every registered id, in registration order.
pub fn all() -> Vec<FamilyId> {
    (0..count()).map(|i| FamilyId(i as u16)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_preregistered_at_enum_indices() {
        for f in Family::all() {
            let id = FamilyId::from(f);
            assert_eq!(id.index(), f.index());
            assert_eq!(id.name(), f.name());
            assert_eq!(resolve(f.name()), Some(id));
            assert_eq!(id.builtin(), Some(f));
            assert_eq!(id, f);
            assert_eq!(f, id);
        }
        assert!(count() >= Family::COUNT);
        assert_eq!(resolve("gpt"), None);
    }

    #[test]
    fn runtime_registration_resolves_and_is_not_a_builtin() {
        let id = register(Box::new(AliasKernel::new(
            "reg-test-alias",
            &DdlmKernel,
        )))
        .unwrap();
        assert_eq!(resolve("reg-test-alias"), Some(id));
        assert_eq!(id.name(), "reg-test-alias");
        assert_eq!(id.kernel().artifact_prefix(), "ddlm");
        assert_eq!(id.builtin(), None);
        assert!(id.index() >= Family::COUNT);
        assert!(all().contains(&id));
        // duplicate names are refused — they key wire routing
        assert_eq!(
            register(Box::new(AliasKernel::new(
                "reg-test-alias",
                &DdlmKernel
            )))
            .unwrap_err(),
            RegistryError::DuplicateName("reg-test-alias".to_string())
        );
        // every behaviour delegates to the wrapped kernel
        assert_eq!(
            id.kernel().times(10, 10.0, 0.05),
            DdlmKernel.times(10, 10.0, 0.05)
        );
        assert_eq!(id.kernel().state_row(64, 512, 48), 64 * 48);
        assert_eq!(id.kernel().time_input(), DdlmKernel.time_input());
    }

    #[test]
    fn unservable_names_are_refused_at_registration() {
        // ':' and ',' delimit CLI fleet/schedule specs, names suffix
        // metrics keys — the registry is the one validation choke point
        for bad in ["", "fast:v2", "a,b", "Upper", "sp ace", "dot.name"] {
            assert_eq!(
                register(Box::new(AliasKernel::new(bad, &DdlmKernel)))
                    .unwrap_err(),
                RegistryError::InvalidName(bad.to_string()),
                "accepted {bad:?}"
            );
            assert_eq!(resolve(bad), None);
        }
    }
}
