//! Generation layer: noise schedules + the batched step-session state
//! machine the coordinator and the experiment harness both drive.

pub mod schedule;
pub mod session;

pub use schedule::{Family, Schedule};
pub use session::{Session, Slot, SlotRequest};
