//! Generation layer: family-polymorphic sampler kernels, noise
//! schedules, and the batched step-session state machine the coordinator
//! and the experiment harness both drive.
//!
//! Per-family behaviour (state width, init, schedule shape, step-tensor
//! packing) lives behind [`kernel::FamilyKernel`]; `Schedule` and
//! `Session` are family-agnostic plumbing over a kernel.  Kernels are
//! addressed by [`registry::FamilyId`] — an open registry seeded with
//! the three built-ins, so out-of-tree kernels registered at runtime
//! are servable end-to-end without touching the `Family` enum.

pub mod kernel;
pub mod registry;
pub mod schedule;
pub mod session;

pub use kernel::{
    DdlmKernel, Family, FamilyKernel, PlaidKernel, SsdKernel, StepOutputs,
};
pub use registry::FamilyId;
pub use schedule::{Schedule, ScheduleError};
pub use session::{
    resident_capable, Session, Slot, SlotError, SlotExport, SlotRequest,
};
