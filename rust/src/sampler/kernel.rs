//! Family-polymorphic sampler kernels.
//!
//! Everything the generation plumbing used to branch on per family —
//! state-row width (`L*D` embedding space vs `L*V` simplex logit
//! space), initial-state synthesis, timestamp-schedule construction
//! (geometric VE vs linear-tau VP), step-input packing and step-output
//! parsing — lives behind the [`FamilyKernel`] trait.  `Session` and
//! `Schedule` are family-agnostic plumbing over a kernel; the three
//! paper families ([`DdlmKernel`], [`SsdKernel`], [`PlaidKernel`]) are
//! the built-in implementations, and a heterogeneous serving fleet can
//! mix workers of different kernels behind one scheduler.

use crate::halting::StepStats;
use crate::util::prng::Prng;

/// Which diffusion parameterisation a family samples under.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub enum Family {
    /// variance-exploding PF-ODE (CDCD / the paper's DDLM), Euler sampler
    Ddlm,
    /// variance-preserving simplex diffusion, "Simplex" sampler
    Ssd,
    /// variance-preserving embedding diffusion, DDPM ancestral sampler
    Plaid,
}

impl Family {
    pub const COUNT: usize = 3;

    /// The family's sampler kernel — the single dispatch point from the
    /// closed enum into the open trait surface.
    pub fn kernel(self) -> &'static dyn FamilyKernel {
        match self {
            Family::Ddlm => &DdlmKernel,
            Family::Ssd => &SsdKernel,
            Family::Plaid => &PlaidKernel,
        }
    }

    /// Dense index for per-family tables (0..COUNT).
    pub fn index(self) -> usize {
        match self {
            Family::Ddlm => 0,
            Family::Ssd => 1,
            Family::Plaid => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        self.kernel().name()
    }

    pub fn parse(s: &str) -> Option<Family> {
        Family::all().into_iter().find(|f| f.name() == s)
    }

    pub fn all() -> [Family; Family::COUNT] {
        [Family::Ddlm, Family::Ssd, Family::Plaid]
    }
}

/// Per-slot scalar outputs of one device step, viewed batch-wide.  The
/// session downloads these once per step; the kernel turns slot `i`'s
/// scalars into the [`StepStats`] the halting policies observe.
pub struct StepOutputs<'a> {
    pub entropy: &'a [f32],
    pub kl: &'a [f32],
    pub switches: &'a [f32],
    pub norm_x0: &'a [f32],
    pub norm_x: &'a [f32],
}

/// One family's generation workflow: everything the family-agnostic
/// `Session`/`Schedule` plumbing must ask a family about.
///
/// Out-of-tree kernels implement this trait and enter serving through
/// [`super::registry::register`]; the wire addresses them by
/// [`Self::name`], and [`Self::artifact_prefix`] lets a wrapper kernel
/// reuse another family's compiled step artifacts and checkpoints.
pub trait FamilyKernel: Send + Sync {
    /// Canonical lowercase name (wire value, metrics suffix).
    fn name(&self) -> &'static str;

    /// Prefix of the compiled step artifacts / checkpoints this kernel
    /// executes (`<prefix>_step_b<batch>_l<seq>`, `<prefix>.pbin`).
    /// Defaults to [`Self::name`]; a registered kernel that varies only
    /// host-side behaviour (schedule shape, init, clamping) points this
    /// at the family whose device artifacts it reuses.
    fn artifact_prefix(&self) -> &'static str {
        self.name()
    }

    /// Diffusion-state row width per slot: `L*D` for embedding-space
    /// families, `L*V` for simplex logit space.
    fn state_row(&self, seq_len: usize, vocab: usize, d_model: usize)
        -> usize;

    /// Timestamp array for `n_steps` generation steps (length
    /// `n_steps + 1`; index i is fed as `t_cur` at step i, index
    /// `n_steps` is the terminal time).  `n_steps >= 1` is guaranteed
    /// by `Schedule::new`.
    fn times(&self, n_steps: usize, t_max: f32, t_min: f32) -> Vec<f32>;

    /// Initial state scale, given the schedule's timestamp array
    /// (multiplied by the caller's noise-scale knob, paper Fig 3 /
    /// Table 1).
    fn init_sigma(&self, times: &[f32]) -> f32;

    /// Synthesize the initial diffusion state into one slot row.
    fn init_state(
        &self,
        x: &mut [f32],
        sigma: f32,
        simplex_k: f32,
        rng: &mut Prng,
    );

    /// Name of the per-step time input tensor in the step artifact.
    fn time_input(&self) -> &'static str;

    /// Whether the step artifact consumes a fresh gaussian noise tensor
    /// `z` every step (stochastic samplers).
    fn needs_z(&self) -> bool;

    /// Neutral, numerically-safe `(t_cur, t_next)` for idle batch slots
    /// (their outputs are ignored).
    fn idle_times(&self) -> (f32, f32);

    /// Whether the session may keep this kernel's generation state
    /// **device-resident** (feed step outputs straight back as the next
    /// step's inputs, host boundary reduced to the `[B]` stat rows —
    /// see `Session` §Perf).  Residency also requires a format-2
    /// artifact whose step inputs include the on-device prefix-clamp
    /// pair (`prefix_mask`/`prefix_x`).
    ///
    /// Default `true`: [`Self::clamp_token`] is per-position pure, so
    /// every built-in's host clamp is exactly representable on the
    /// device.  An out-of-tree kernel that mutates host-side state
    /// between steps in ways the step artifact cannot express opts out
    /// here, and its sessions stay on the host-roundtrip path.
    fn supports_device_residency(&self) -> bool {
        true
    }

    /// Whether this kernel's per-position token lanes (token entropy,
    /// argmax-changed flags from the fused stat tensor) are meaningful
    /// for token-level freeze decisions.  Default `true`: every
    /// built-in's argmax/probs are per-position pure.  An out-of-tree
    /// kernel whose decode mixes positions (e.g. a host-side rescoring
    /// pass) opts out here; its sessions then never expose token lanes
    /// and token-level policies (`tokstab`/`tokentropy`) stay inert.
    fn supports_token_halting(&self) -> bool {
        true
    }

    /// Device shape of the state tensor for a batch.
    fn x_shape(
        &self,
        batch: usize,
        seq_len: usize,
        vocab: usize,
        d_model: usize,
    ) -> [usize; 3] {
        let row = self.state_row(seq_len, vocab, d_model);
        [batch, seq_len, row / seq_len]
    }

    /// Overwrite one prefix position with its clean representation —
    /// replacement conditioning.  `dst` is that position's slice of the
    /// state row; `emb_row` is the (normalised) embedding row of `tok`.
    fn clamp_token(
        &self,
        dst: &mut [f32],
        tok: usize,
        emb_row: &[f32],
        simplex_k: f32,
    );

    /// Parse slot `i`'s step outputs into the stats the halting
    /// policies observe.  The default reads the shared per-slot scalar
    /// outputs; a kernel with extra signals may override.
    fn parse_stats(&self, slot: usize, out: &StepOutputs<'_>) -> StepStats {
        StepStats {
            entropy: out.entropy[slot],
            kl: out.kl[slot],
            switches: out.switches[slot],
            norm_x0: out.norm_x0[slot],
            norm_x: out.norm_x[slot],
        }
    }
}

/// Variance-exploding PF-ODE over normalised embeddings (CDCD / the
/// paper's DDLM): geometric (Karras-style) schedule from `t_max` down
/// to `t_min`, deterministic Euler steps, `X(t_max) ~ N(0, t_max^2 I)`.
pub struct DdlmKernel;

impl FamilyKernel for DdlmKernel {
    fn name(&self) -> &'static str {
        "ddlm"
    }

    fn state_row(
        &self,
        seq_len: usize,
        _vocab: usize,
        d_model: usize,
    ) -> usize {
        seq_len * d_model
    }

    fn times(&self, n_steps: usize, t_max: f32, t_min: f32) -> Vec<f32> {
        // geometric (log-uniform) from t_max down to t_min
        let ratio = (t_min / t_max).max(1e-6) as f64;
        (0..=n_steps)
            .map(|i| {
                let f = i as f64 / n_steps as f64;
                (t_max as f64 * ratio.powf(f)) as f32
            })
            .collect()
    }

    fn init_sigma(&self, times: &[f32]) -> f32 {
        // X(t_max) ~ N(0, t_max^2 I)
        times[0]
    }

    fn init_state(
        &self,
        x: &mut [f32],
        sigma: f32,
        _simplex_k: f32,
        rng: &mut Prng,
    ) {
        for xi in x.iter_mut() {
            *xi = sigma * rng.gaussian() as f32;
        }
    }

    fn time_input(&self) -> &'static str {
        "t2"
    }

    fn needs_z(&self) -> bool {
        false
    }

    fn idle_times(&self) -> (f32, f32) {
        (1.0, 1.0)
    }

    fn clamp_token(
        &self,
        dst: &mut [f32],
        _tok: usize,
        emb_row: &[f32],
        _simplex_k: f32,
    ) {
        dst.copy_from_slice(emb_row);
    }
}

/// Variance-preserving simplex diffusion ("Simplex" sampler): linear
/// tau schedule, `L*V` logit-space state initialised at `K * z`, fresh
/// noise every step.
pub struct SsdKernel;

/// Linear tau in `[tau0, 1]`; `tau0 > 0` keeps `abar_cur` strictly
/// inside `(0, 1)` for the DDPM coefficients.
fn vp_times(n_steps: usize) -> Vec<f32> {
    let tau0 = 1e-3;
    (0..=n_steps)
        .map(|i| tau0 + (1.0 - tau0) * (i as f32 / n_steps as f32))
        .collect()
}

impl FamilyKernel for SsdKernel {
    fn name(&self) -> &'static str {
        "ssd"
    }

    fn state_row(
        &self,
        seq_len: usize,
        vocab: usize,
        _d_model: usize,
    ) -> usize {
        seq_len * vocab
    }

    fn times(&self, n_steps: usize, _t_max: f32, _t_min: f32) -> Vec<f32> {
        vp_times(n_steps)
    }

    fn init_sigma(&self, _times: &[f32]) -> f32 {
        // simplex logit space: K * sqrt(1 - abar(tau0)) ~ K
        1.0
    }

    fn init_state(
        &self,
        x: &mut [f32],
        sigma: f32,
        simplex_k: f32,
        rng: &mut Prng,
    ) {
        // logit-space init: x = K * z at max noise (abar ~ 0)
        for xi in x.iter_mut() {
            *xi = simplex_k * sigma * rng.gaussian() as f32;
        }
    }

    fn time_input(&self) -> &'static str {
        "tau2"
    }

    fn needs_z(&self) -> bool {
        true
    }

    fn idle_times(&self) -> (f32, f32) {
        (0.5, 0.5)
    }

    fn clamp_token(
        &self,
        dst: &mut [f32],
        tok: usize,
        _emb_row: &[f32],
        simplex_k: f32,
    ) {
        for (j, xj) in dst.iter_mut().enumerate() {
            *xj = if j == tok { simplex_k } else { -simplex_k };
        }
    }
}

/// Variance-preserving embedding diffusion (Plaid), DDPM ancestral
/// sampler: linear tau schedule, unit-gaussian `L*D` init, fresh noise
/// every step.
pub struct PlaidKernel;

impl FamilyKernel for PlaidKernel {
    fn name(&self) -> &'static str {
        "plaid"
    }

    fn state_row(
        &self,
        seq_len: usize,
        _vocab: usize,
        d_model: usize,
    ) -> usize {
        seq_len * d_model
    }

    fn times(&self, n_steps: usize, _t_max: f32, _t_min: f32) -> Vec<f32> {
        vp_times(n_steps)
    }

    fn init_sigma(&self, _times: &[f32]) -> f32 {
        // VP embedding space: unit gaussian at tau ~ 0
        1.0
    }

    fn init_state(
        &self,
        x: &mut [f32],
        sigma: f32,
        _simplex_k: f32,
        rng: &mut Prng,
    ) {
        for xi in x.iter_mut() {
            *xi = sigma * rng.gaussian() as f32;
        }
    }

    fn time_input(&self) -> &'static str {
        "tau2"
    }

    fn needs_z(&self) -> bool {
        true
    }

    fn idle_times(&self) -> (f32, f32) {
        (0.5, 0.5)
    }

    fn clamp_token(
        &self,
        dst: &mut [f32],
        _tok: usize,
        emb_row: &[f32],
        _simplex_k: f32,
    ) {
        dst.copy_from_slice(emb_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_parse_roundtrip_and_index_is_dense() {
        for (i, f) in Family::all().into_iter().enumerate() {
            assert_eq!(Family::parse(f.name()), Some(f));
            assert_eq!(f.index(), i);
            assert_eq!(f.kernel().name(), f.name());
            // built-ins run their own artifacts
            assert_eq!(f.kernel().artifact_prefix(), f.name());
        }
        assert_eq!(Family::parse("gpt"), None);
        assert_eq!(Family::all().len(), Family::COUNT);
    }

    #[test]
    fn ddlm_times_are_decreasing_geometric() {
        let k = Family::Ddlm.kernel();
        let t = k.times(100, 10.0, 0.05);
        assert_eq!(t.len(), 101);
        assert!((t[0] - 10.0).abs() < 1e-5);
        assert!((t[100] - 0.05).abs() < 1e-4);
        for w in t.windows(2) {
            assert!(w[1] < w[0], "must decrease");
        }
        // geometric: ratio roughly constant
        let r0 = t[1] / t[0];
        let r50 = t[51] / t[50];
        assert!((r0 - r50).abs() < 1e-4);
        // init sigma tracks the starting time
        assert!((k.init_sigma(&t) - 10.0).abs() < 1e-5);
    }

    #[test]
    fn vp_times_are_increasing_to_one() {
        for fam in [Family::Ssd, Family::Plaid] {
            let k = fam.kernel();
            let t = k.times(50, 10.0, 0.05);
            assert!(t[0] > 0.0 && t[0] < 0.01);
            assert!((t[50] - 1.0).abs() < 1e-6);
            for w in t.windows(2) {
                assert!(w[1] > w[0]);
            }
            // VP families start from a unit-scale state
            assert_eq!(k.init_sigma(&t), 1.0);
        }
    }

    #[test]
    fn state_widths_split_embedding_vs_simplex() {
        let (l, v, d) = (64, 512, 48);
        assert_eq!(Family::Ddlm.kernel().state_row(l, v, d), l * d);
        assert_eq!(Family::Plaid.kernel().state_row(l, v, d), l * d);
        assert_eq!(Family::Ssd.kernel().state_row(l, v, d), l * v);
        // x_shape is consistent with the row width
        for f in Family::all() {
            let k = f.kernel();
            let [b, sl, w] = k.x_shape(8, l, v, d);
            assert_eq!((b, sl), (8, l));
            assert_eq!(sl * w, k.state_row(l, v, d));
        }
    }

    #[test]
    fn step_input_contract_per_family() {
        assert_eq!(Family::Ddlm.kernel().time_input(), "t2");
        assert!(!Family::Ddlm.kernel().needs_z());
        for fam in [Family::Ssd, Family::Plaid] {
            assert_eq!(fam.kernel().time_input(), "tau2");
            assert!(fam.kernel().needs_z());
        }
        // every built-in clamp is per-position pure, so all built-ins
        // serve on the device-resident path
        for fam in Family::all() {
            assert!(fam.kernel().supports_device_residency());
        }
    }

    #[test]
    fn init_state_scales_per_family() {
        let mut rng = Prng::new(7);
        let mut x = vec![0.0f32; 256];
        let k_simplex = 5.0f32;
        Family::Ddlm.kernel().init_state(&mut x, 10.0, k_simplex, &mut rng);
        let rms =
            (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt();
        assert!((rms - 10.0).abs() < 2.0, "ddlm rms={rms}");
        Family::Ssd.kernel().init_state(&mut x, 1.0, k_simplex, &mut rng);
        let rms =
            (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt();
        assert!((rms - k_simplex).abs() < 1.0, "ssd rms={rms}");
        Family::Plaid.kernel().init_state(&mut x, 1.0, k_simplex, &mut rng);
        let rms =
            (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt();
        assert!((rms - 1.0).abs() < 0.2, "plaid rms={rms}");
    }

    #[test]
    fn clamp_token_writes_clean_representation() {
        let emb_row = [1.0f32, 2.0, 3.0];
        let mut dst = [0.0f32; 3];
        Family::Ddlm.kernel().clamp_token(&mut dst, 1, &emb_row, 5.0);
        assert_eq!(dst, emb_row);
        let mut logits = [0.0f32; 4];
        Family::Ssd.kernel().clamp_token(&mut logits, 2, &emb_row, 5.0);
        assert_eq!(logits, [-5.0, -5.0, 5.0, -5.0]);
    }

    #[test]
    fn parse_stats_reads_slot_scalars() {
        let out = StepOutputs {
            entropy: &[0.1, 0.2],
            kl: &[1e-3, 2e-3],
            switches: &[3.0, 4.0],
            norm_x0: &[8.0, 9.0],
            norm_x: &[10.0, 11.0],
        };
        for f in Family::all() {
            let st = f.kernel().parse_stats(1, &out);
            assert_eq!(st.entropy, 0.2);
            assert_eq!(st.kl, 2e-3);
            assert_eq!(st.switches, 4.0);
            assert_eq!(st.norm_x0, 9.0);
            assert_eq!(st.norm_x, 11.0);
        }
    }
}
