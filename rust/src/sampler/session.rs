//! Batched generation session: the state machine around one step artifact.
//!
//! A `Session` owns the diffusion state for `B` independent slots and
//! advances all of them with one device call per step.  Each slot has its
//! own schedule position, noise stream, and (optional) conditioning
//! prefix, which is exactly what the coordinator's continuous batcher
//! needs: a slot whose request halted early is reset and reused while the
//! other slots keep denoising mid-schedule.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::schedule::{Family, Schedule};
use crate::halting::StepStats;
use crate::models::store::ParamStore;
use crate::runtime::{Executable, Runtime, Tensor};
use crate::util::prng::Prng;

/// Per-slot generation state.
#[derive(Clone, Debug)]
pub struct Slot {
    /// schedule position (next step index to execute)
    pub step: usize,
    /// per-slot schedule (requests may ask for different step counts)
    pub schedule: Schedule,
    /// slot is occupied and still denoising
    pub active: bool,
    /// per-slot noise stream
    rng: Prng,
    /// conditioning prefix tokens (Prefix-32 task), clamped every step
    prefix: Vec<i32>,
    /// latest argmax tokens (decoded output)
    pub tokens: Vec<i32>,
    /// latest step statistics
    pub last_stats: StepStats,
}

pub struct Session {
    pub family: Family,
    exe: Rc<Executable>,
    store: Rc<ParamStore>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// state row width: L*D (ddlm/plaid) or L*V (ssd)
    row: usize,
    /// diffusion state [B, row]
    x: Vec<f32>,
    prev_probs: Vec<f32>,
    prev_tokens: Vec<i32>,
    pub slots: Vec<Slot>,
    /// normalised embedding rows [V, D] for prefix clamping
    emb_n: Vec<f32>,
    simplex_k: f32,
    /// input-name for the time tensor ("t2" for ddlm, "tau2" for VP)
    time_input: &'static str,
    needs_z: bool,
    /// latest x0_hat download [B, L*D] (Fig-2 trajectory analysis)
    last_x0_hat: Vec<f32>,
    /// persistent device buffers for the (immutable) parameters, uploaded
    /// once — (input index, buffer); §Perf: params are ~70 % of the
    /// per-step input bytes and never change during generation
    param_bufs: Vec<(usize, crate::runtime::client::DeviceTensor)>,
    /// input indices of the per-step data tensors, in spec order
    data_idx: Vec<(String, usize)>,
    /// steps executed (device calls)
    pub device_calls: u64,
}

impl Session {
    /// Create a session bound to `<family>_step_b<batch>_l<seq_len>`.
    pub fn new(
        rt: &Runtime,
        family: Family,
        store: Rc<ParamStore>,
        batch: usize,
        seq_len: usize,
    ) -> Result<Session> {
        let name = format!("{}_step_b{batch}_l{seq_len}", family.name());
        let exe = rt.executable(&name)?;
        let m = &rt.manifest.model;
        let (v, d) = (m.vocab, m.d_model);
        let row = match family {
            Family::Ssd => seq_len * v,
            _ => seq_len * d,
        };
        // normalised embeddings (CDCD: rows scaled to sqrt(D))
        let emb = store.get("emb")?.as_f32()?.to_vec();
        if emb.len() != v * d {
            bail!("emb shape mismatch");
        }
        let target = (d as f32).sqrt();
        let mut emb_n = emb;
        for r in 0..v {
            let row_sl = &mut emb_n[r * d..(r + 1) * d];
            let n = row_sl.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-8);
            for x in row_sl.iter_mut() {
                *x *= target / n;
            }
        }
        // upload immutable parameters to persistent device buffers once
        let mut param_bufs = Vec::new();
        let mut data_idx = Vec::new();
        for (i, input) in exe.spec.inputs.iter().enumerate() {
            if let Some(t) = store.tensors.get(&input.name) {
                param_bufs.push((i, exe.buffer_from_tensor(t)?));
            } else {
                data_idx.push((input.name.clone(), i));
            }
        }
        let default_schedule =
            Schedule::new(family, 1, m.t_max, m.t_min);
        let slots = (0..batch)
            .map(|_| Slot {
                step: 0,
                schedule: default_schedule.clone(),
                active: false,
                rng: Prng::new(0),
                prefix: Vec::new(),
                tokens: vec![0; seq_len],
                last_stats: StepStats::default(),
            })
            .collect();
        Ok(Session {
            family,
            exe,
            store,
            batch,
            seq_len,
            vocab: v,
            d_model: d,
            row,
            x: vec![0.0; batch * row],
            prev_probs: vec![1.0 / v as f32; batch * seq_len * v],
            prev_tokens: vec![0; batch * seq_len],
            slots,
            emb_n,
            simplex_k: m.simplex_k,
            time_input: match family {
                Family::Ddlm => "t2",
                _ => "tau2",
            },
            needs_z: !matches!(family, Family::Ddlm),
            last_x0_hat: vec![0.0; batch * seq_len * d],
            param_bufs,
            data_idx,
            device_calls: 0,
        })
    }

    /// Occupy a slot with a fresh request: initialise noise, schedule and
    /// optional conditioning prefix.
    pub fn reset_slot(
        &mut self,
        slot: usize,
        seed: u64,
        n_steps: usize,
        noise_scale: f32,
        t_max: f32,
        t_min: f32,
        prefix: &[i32],
    ) {
        assert!(slot < self.batch);
        assert!(prefix.len() <= self.seq_len);
        let schedule = Schedule::new(self.family, n_steps, t_max, t_min);
        let mut rng = Prng::new(seed).fork("gen-noise");
        let sigma = schedule.init_sigma() * noise_scale;
        let (l, v) = (self.seq_len, self.vocab);
        let base = slot * self.row;
        match self.family {
            Family::Ddlm | Family::Plaid => {
                for i in 0..self.row {
                    self.x[base + i] = sigma * rng.gaussian() as f32;
                }
            }
            Family::Ssd => {
                // logit-space init: x = K * z at max noise (abar ~ 0)
                for i in 0..self.row {
                    self.x[base + i] =
                        self.simplex_k * sigma * rng.gaussian() as f32;
                }
            }
        }
        let pb = slot * l * v;
        for p in &mut self.prev_probs[pb..pb + l * v] {
            *p = 1.0 / v as f32;
        }
        let tb = slot * l;
        for t in &mut self.prev_tokens[tb..tb + l] {
            *t = 0;
        }
        for (i, &tok) in prefix.iter().enumerate() {
            self.prev_tokens[tb + i] = tok;
        }
        let s = &mut self.slots[slot];
        s.step = 0;
        s.schedule = schedule;
        s.active = true;
        s.rng = rng;
        s.prefix = prefix.to_vec();
        s.tokens = self.prev_tokens[tb..tb + l].to_vec();
        s.last_stats = StepStats::default();
        self.clamp_prefix(slot);
    }

    /// Mark a slot free (halted / finished / cancelled).
    pub fn release_slot(&mut self, slot: usize) {
        self.slots[slot].active = false;
    }

    pub fn any_active(&self) -> bool {
        self.slots.iter().any(|s| s.active)
    }

    /// Overwrite prefix positions with their clean representation —
    /// replacement conditioning, matching how prefix-masked training kept
    /// unmasked positions clean at every noise level.
    fn clamp_prefix(&mut self, slot: usize) {
        let l = self.seq_len;
        let (v, d) = (self.vocab, self.d_model);
        let prefix = self.slots[slot].prefix.clone();
        let base = slot * self.row;
        for (pos, &tok) in prefix.iter().enumerate() {
            let tok = tok.clamp(0, v as i32 - 1) as usize;
            match self.family {
                Family::Ddlm | Family::Plaid => {
                    let dst = base + pos * d;
                    let src = tok * d;
                    self.x[dst..dst + d]
                        .copy_from_slice(&self.emb_n[src..src + d]);
                }
                Family::Ssd => {
                    let dst = base + pos * v;
                    for (j, xj) in self.x[dst..dst + v].iter_mut().enumerate()
                    {
                        *xj = if j == tok {
                            self.simplex_k
                        } else {
                            -self.simplex_k
                        };
                    }
                }
            }
        }
        let _ = l;
    }

    /// Advance every active slot by one diffusion step (one device call).
    /// Inactive slots are stepped with neutral times and ignored.
    /// Returns per-slot stats for slots that were active.
    pub fn step(&mut self) -> Result<Vec<Option<StepStats>>> {
        let (b, l, v) = (self.batch, self.seq_len, self.vocab);
        // per-slot (t_cur, t_next)
        let mut t2 = vec![0.0f32; b * 2];
        for (i, s) in self.slots.iter().enumerate() {
            let (c, n) = if s.active && s.step < s.schedule.n_steps() {
                s.schedule.pair(s.step)
            } else {
                // neutral, numerically-safe times for idle slots
                match self.family {
                    Family::Ddlm => (1.0, 1.0),
                    _ => (0.5, 0.5),
                }
            };
            t2[i * 2] = c;
            t2[i * 2 + 1] = n;
        }

        let mut data: BTreeMap<String, Tensor> = BTreeMap::new();
        let x_shape: Vec<usize> = match self.family {
            Family::Ssd => vec![b, l, v],
            _ => vec![b, l, self.d_model],
        };
        data.insert("x_t".to_string(), Tensor::f32(&x_shape, self.x.clone()));
        data.insert(
            "prev_probs".to_string(),
            Tensor::f32(&[b, l, v], self.prev_probs.clone()),
        );
        data.insert(
            "prev_tokens".to_string(),
            Tensor::i32(&[b, l], self.prev_tokens.clone()),
        );
        data.insert(self.time_input.to_string(), Tensor::f32(&[b, 2], t2));
        if self.needs_z {
            let mut z = vec![0.0f32; b * self.row];
            for (i, s) in self.slots.iter_mut().enumerate() {
                if s.active {
                    s.rng.fill_gaussian_f32(
                        &mut z[i * self.row..(i + 1) * self.row],
                    );
                }
            }
            data.insert("z".to_string(), Tensor::f32(&x_shape, z));
        }

        // assemble device buffers: persistent param buffers + fresh data
        // buffers (only the per-step tensors cross the host boundary)
        let mut data_bufs = Vec::with_capacity(self.data_idx.len());
        for (name, i) in &self.data_idx {
            let t = data
                .remove(name.as_str())
                .ok_or_else(|| anyhow::anyhow!("missing data input {name}"))?;
            data_bufs.push((*i, self.exe.buffer_from_tensor(&t)?));
        }
        let n_inputs = self.exe.spec.inputs.len();
        let mut slots_in: Vec<Option<&xla::PjRtBuffer>> = vec![None; n_inputs];
        for (i, b) in &self.param_bufs {
            slots_in[*i] = Some(&b.buf);
        }
        for (i, b) in &data_bufs {
            slots_in[*i] = Some(&b.buf);
        }
        let refs: Vec<&xla::PjRtBuffer> = slots_in
            .into_iter()
            .map(|o| o.expect("input gap"))
            .collect();
        let out_lits = self.exe.run_buffers(&refs).context("step execute")?;
        let out = self.exe.download(out_lits)?;
        self.device_calls += 1;

        let spec = &self.exe.spec;
        let x_next = out[spec.output_index("x_next")?].as_f32()?;
        let probs = out[spec.output_index("probs")?].as_f32()?;
        let tokens = out[spec.output_index("tokens")?].as_i32()?;
        let entropy = out[spec.output_index("entropy")?].as_f32()?;
        let kl = out[spec.output_index("kl")?].as_f32()?;
        let switches = out[spec.output_index("switches")?].as_f32()?;
        let norm_x0 = out[spec.output_index("norm_x0")?].as_f32()?;
        let norm_x = out[spec.output_index("norm_x")?].as_f32()?;
        let x0_hat = out[spec.output_index("x0_hat")?].as_f32()?;

        let mut results = Vec::with_capacity(b);
        for i in 0..b {
            if !self.slots[i].active {
                results.push(None);
                continue;
            }
            // commit state for this slot
            let xb = i * self.row;
            self.x[xb..xb + self.row]
                .copy_from_slice(&x_next[xb..xb + self.row]);
            let pb = i * l * v;
            self.prev_probs[pb..pb + l * v]
                .copy_from_slice(&probs[pb..pb + l * v]);
            let tb = i * l;
            self.prev_tokens[tb..tb + l]
                .copy_from_slice(&tokens[tb..tb + l]);
            let w = l * self.d_model;
            self.last_x0_hat[i * w..(i + 1) * w]
                .copy_from_slice(&x0_hat[i * w..(i + 1) * w]);
            let stats = StepStats {
                entropy: entropy[i],
                kl: kl[i],
                switches: switches[i],
                norm_x0: norm_x0[i],
                norm_x: norm_x[i],
            };
            let slot = &mut self.slots[i];
            slot.tokens.copy_from_slice(&tokens[tb..tb + l]);
            slot.last_stats = stats;
            slot.step += 1;
            results.push(Some(stats));
        }
        // re-clamp prefixes after the state update
        for i in 0..b {
            if self.slots[i].active && !self.slots[i].prefix.is_empty() {
                self.clamp_prefix(i);
            }
        }
        Ok(results)
    }

    /// Current diffusion-state row of a slot (L*D for ddlm/plaid, L*V for
    /// ssd) — used by the Fig-2 trajectory analysis.
    pub fn slot_x(&self, slot: usize) -> &[f32] {
        &self.x[slot * self.row..(slot + 1) * self.row]
    }

    /// Latest x0_hat row of a slot (always L*D) — Fig-2 score analysis.
    pub fn slot_x0_hat(&self, slot: usize) -> &[f32] {
        let w = self.seq_len * self.d_model;
        &self.last_x0_hat[slot * w..(slot + 1) * w]
    }

    /// Decoded tokens of a slot (prefix positions forced to the prefix).
    pub fn slot_output(&self, slot: usize) -> Vec<i32> {
        let s = &self.slots[slot];
        let mut out = s.tokens.clone();
        for (i, &t) in s.prefix.iter().enumerate() {
            out[i] = t;
        }
        out
    }

    /// True when a slot has exhausted its schedule.
    pub fn slot_exhausted(&self, slot: usize) -> bool {
        let s = &self.slots[slot];
        s.step >= s.schedule.n_steps()
    }

    /// Hot-loop accounting (per-call stats live on the executable).
    pub fn exec_stats(&self) -> crate::runtime::ExecStats {
        self.exe.stats()
    }
}
