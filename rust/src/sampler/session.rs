//! Batched generation session: the state machine around one step artifact.
//!
//! A `Session` owns the diffusion state for `B` independent slots and
//! advances all of them with one device call per step.  Each slot has its
//! own schedule position, noise stream, and (optional) conditioning
//! prefix, which is exactly what the coordinator's continuous batcher
//! needs: a slot whose request halted early is reset and reused while the
//! other slots keep denoising mid-schedule.
//!
//! The session is family-agnostic plumbing: everything per-family —
//! state-row width, init synthesis, schedule shape, step-input packing,
//! step-output parsing — is delegated to the slot's
//! [`FamilyKernel`](super::kernel::FamilyKernel).
//!
//! §Perf — two step paths, one contract (see ROADMAP §Perf):
//!
//! * **Device-resident** (the default on format-2 artifacts whose step
//!   inputs include `prefix_mask`/`prefix_x`): step N's `x_next` /
//!   `probs` / `tokens` output buffers are fed straight back as step
//!   N+1's `x_t` / `prev_probs` / `prev_tokens` inputs — the `[B,L,V]`
//!   probability tensor and the `[B,row]` state never cross the host
//!   boundary in steady state.  Per step the host uploads only the
//!   `[B,2]` times (plus the noise scratch for `needs_z` kernels) and
//!   downloads exactly ONE tensor: the fused `[B, 5+2L]` stat output
//!   (format-3 artifacts; the five `[B]` stat rows stacked with the
//!   per-position token-entropy / argmax-changed lanes driving
//!   token-level freeze decisions).  Format-2 artifacts fall back to
//!   five split `[B]` stat downloads with token halting unavailable;
//!   decoded tokens download lazily ([`Session::slot_output`]).  Prefix
//!   clamping happens on the device through the `prefix_mask`/`prefix_x`
//!   step inputs, which are re-uploaded only when a reset changes them.
//! * **Host-roundtrip reference** (format-1 artifacts, runtimes whose
//!   PJRT hands back un-decomposed tuple buffers, explicit
//!   [`Session::set_resident`]`(false)`, and the Fig-2
//!   [`Session::set_record_x0`] trajectory mode): every step's outputs
//!   materialise into the session's host mirrors, exactly the pre-PR-5
//!   behaviour — the equivalence baseline the resident path is tested
//!   against (`tests/residency_equivalence.rs`).
//!
//! Host mutation points go through a per-slot **dirty protocol**:
//! [`Session::reset_slot`] rewrites the slot's host-mirror rows and
//! marks the slot dirty; the next resident step folds the device rows
//! of the *other* (non-dirty) slots into the mirrors, re-uploads the
//! merged state once, and goes resident again.  The full roundtrip is
//! paid only on steps where a reset actually happened.
//!
//! Hot-loop allocation discipline: the per-step input table reuses
//! persistent scratch (no `Vec` allocation per device call), and prefix
//! clamping split-borrows the slot instead of cloning its prefix.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::kernel::{FamilyKernel, StepOutputs};
use super::registry::FamilyId;
use super::schedule::{Schedule, ScheduleError};
use crate::halting::StepStats;
use crate::log_warn;
use crate::models::store::ParamStore;
use crate::runtime::client::{DeviceTensor, TupleNotDecomposed};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::{Executable, Runtime};
use crate::util::prng::Prng;

/// Typed slot-reset failure.  The serving path rejects both cases at
/// admission; this surfaces the same contract to direct library callers
/// (and lets a worker answer a mis-validated request with a typed
/// `invalid_request` instead of panicking).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotError {
    /// `n_steps == 0`: no schedule can be built (zero-step budgets are
    /// answered before touching a session)
    ZeroSteps,
    /// conditioning prefix longer than the compiled sequence length
    PrefixTooLong { len: usize, max: usize },
}

impl std::fmt::Display for SlotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlotError::ZeroSteps => {
                f.write_str("slot request needs at least one step")
            }
            SlotError::PrefixTooLong { len, max } => write!(
                f,
                "prefix of {len} tokens exceeds the compiled seq_len {max}"
            ),
        }
    }
}

impl std::error::Error for SlotError {}

impl From<ScheduleError> for SlotError {
    fn from(e: ScheduleError) -> SlotError {
        match e {
            ScheduleError::ZeroSteps => SlotError::ZeroSteps,
        }
    }
}

/// Everything `reset_slot` needs to occupy a slot with a fresh request.
#[derive(Clone, Copy, Debug)]
pub struct SlotRequest<'a> {
    pub seed: u64,
    /// maximum diffusion steps (N_max)
    pub n_steps: usize,
    /// initial noise scale (paper Fig 3 / Table 1 knob)
    pub noise_scale: f32,
    pub t_max: f32,
    pub t_min: f32,
    /// conditioning prefix tokens (empty = unconditional)
    pub prefix: &'a [i32],
}

impl<'a> SlotRequest<'a> {
    /// Unconditional request at the default noise scale (1.0); chain
    /// [`Self::noise`] / [`Self::prefix`] for the rest.
    pub fn new(
        seed: u64,
        n_steps: usize,
        t_max: f32,
        t_min: f32,
    ) -> SlotRequest<'a> {
        SlotRequest {
            seed,
            n_steps,
            noise_scale: 1.0,
            t_max,
            t_min,
            prefix: &[],
        }
    }

    pub fn noise(mut self, scale: f32) -> SlotRequest<'a> {
        self.noise_scale = scale;
        self
    }

    pub fn prefix(mut self, prefix: &'a [i32]) -> SlotRequest<'a> {
        self.prefix = prefix;
        self
    }
}

/// Per-slot generation state.
#[derive(Clone, Debug)]
pub struct Slot {
    /// schedule position (next step index to execute)
    pub step: usize,
    /// per-slot schedule (requests may ask for different step counts)
    pub schedule: Schedule,
    /// slot is occupied and still denoising
    pub active: bool,
    /// per-slot noise stream
    rng: Prng,
    /// conditioning prefix tokens (Prefix-32 task), clamped every step
    prefix: Vec<i32>,
    /// latest argmax tokens (decoded output; refreshed lazily on the
    /// resident path — see [`Session::slot_output`])
    pub tokens: Vec<i32>,
    /// latest step statistics
    pub last_stats: StepStats,
}

/// A slot's complete generation state, detached from any session — the
/// migration unit of the elastic fleet.  [`Session::export_slot`]
/// produces one; [`Session::import_slot`] resumes it on another session
/// of the SAME family and compiled seq_len (any batch size: per-row
/// math never reduces across the batch axis, so a row stepped on a b1
/// shard is bit-identical to the same row on a b8 shard —
/// `tests/migration_equivalence.rs` pins this).  Everything the step
/// needs travels: diffusion state row, probability/token feedback,
/// schedule position, the noise stream mid-sequence, and the pinned
/// clamp rows (conditioning prefix + token-level freezes), so frozen
/// positions stay frozen at the same values on the destination shard.
#[derive(Clone, Debug)]
pub struct SlotExport {
    pub family: FamilyId,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// schedule position (next step index to execute)
    pub step: usize,
    pub schedule: Schedule,
    rng: Prng,
    prefix: Vec<i32>,
    pub tokens: Vec<i32>,
    pub last_stats: StepStats,
    /// diffusion-state row `[row]` (kernel width: L*D or L*V)
    x_row: Vec<f32>,
    /// probability feedback row `[L*V]`
    prev_probs_row: Vec<f32>,
    /// token feedback row `[L]`
    prev_tokens_row: Vec<i32>,
    /// pinned-position mask `[L]` (prefix + freezes)
    prefix_mask_row: Vec<f32>,
    /// clean clamp state row `[row]`
    prefix_x_row: Vec<f32>,
    /// freeze-only mask `[L]` (subset of `prefix_mask_row`)
    frozen_row: Vec<f32>,
    /// token pinned at each frozen position `[L]`
    frozen_vals_row: Vec<i32>,
    frozen_count: usize,
}

impl SlotExport {
    /// Steps remaining in the exported schedule — what a migration
    /// reclaims on the source shard.
    pub fn steps_remaining(&self) -> usize {
        self.schedule.n_steps().saturating_sub(self.step)
    }

    /// Count of freeze-pinned positions travelling with the slot.
    pub fn frozen_count(&self) -> usize {
        self.frozen_count
    }

    /// Test-only: a synthetic export for scheduler/queue unit tests
    /// that never touch a device (the real constructor is
    /// [`Session::export_slot`]).
    #[cfg(test)]
    pub(crate) fn synthetic(
        family: FamilyId,
        n_steps: usize,
        step: usize,
    ) -> SlotExport {
        SlotExport {
            family,
            seq_len: 0,
            vocab: 0,
            d_model: 0,
            step,
            schedule: Schedule::new(family, n_steps.max(1), 10.0, 0.05)
                .expect("synthetic schedule"),
            rng: Prng::new(0),
            prefix: Vec::new(),
            tokens: Vec::new(),
            last_stats: StepStats::default(),
            x_row: Vec::new(),
            prev_probs_row: Vec::new(),
            prev_tokens_row: Vec::new(),
            prefix_mask_row: Vec::new(),
            prefix_x_row: Vec::new(),
            frozen_row: Vec::new(),
            frozen_vals_row: Vec::new(),
            frozen_count: 0,
        }
    }
}

/// Step-artifact output indices, resolved once at session build so the
/// hot loop never does name lookups.
struct StepOutIdx {
    x_next: usize,
    probs: usize,
    tokens: usize,
    entropy: usize,
    kl: usize,
    switches: usize,
    norm_x0: usize,
    norm_x: usize,
    x0_hat: usize,
    /// format-3 fused stat tensor `[B, 5+2L]` (the five scalar rows
    /// stacked with the token-entropy and argmax-changed lanes);
    /// `None` on format-2 artifacts, which fall back to the five-row
    /// split download
    stats_fused: Option<usize>,
}

/// Which per-step data tensor an artifact input consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DataKind {
    X,
    PrevProbs,
    PrevTokens,
    Z,
    Time,
    PrefixMask,
    PrefixX,
}

/// Where one artifact input comes from, resolved once at session build:
/// a persistent parameter buffer or a per-step data tensor.
enum Src {
    Param(usize),
    Data(DataKind),
}

/// Device-resident generation state: the previous step's output buffers,
/// fed back as the next step's inputs without touching the host.
struct DevState {
    x: xla::PjRtBuffer,
    probs: xla::PjRtBuffer,
    tokens: xla::PjRtBuffer,
}

/// Per-step upload slots, reused every device call (old buffers drop on
/// overwrite).  On the resident steady path only `time` (and `z` for
/// stochastic kernels) are populated; the state slots fill only on
/// dirty-sync steps and on the reference path.
#[derive(Default)]
struct StepUploads {
    x: Option<DeviceTensor>,
    prev_probs: Option<DeviceTensor>,
    prev_tokens: Option<DeviceTensor>,
    z: Option<DeviceTensor>,
    time: Option<DeviceTensor>,
}

/// True when a step artifact carries the format-2 on-device
/// prefix-clamp inputs the resident path requires.
pub fn resident_capable(spec: &ArtifactSpec) -> bool {
    spec.has_input("prefix_mask") && spec.has_input("prefix_x")
}

pub struct Session {
    /// registry handle of the serving kernel (built-in or registered)
    pub family: FamilyId,
    /// the family's sampler kernel — all per-family behaviour routes
    /// through this one seam
    kernel: &'static dyn FamilyKernel,
    exe: Rc<Executable>,
    store: Rc<ParamStore>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// state row width per slot (kernel-defined: L*D or L*V)
    row: usize,
    /// diffusion-state host mirror [B, row] (authoritative on the
    /// reference path; on the resident path authoritative only while
    /// `state_synced`)
    x: Vec<f32>,
    prev_probs: Vec<f32>,
    prev_tokens: Vec<i32>,
    pub slots: Vec<Slot>,
    /// normalised embedding rows [V, D] for prefix clamping
    emb_n: Vec<f32>,
    simplex_k: f32,
    /// per-step (t_cur, t_next) upload scratch [B, 2], reused every step
    t2_scratch: Vec<f32>,
    /// per-step noise upload scratch [B, row], reused every step
    z_scratch: Vec<f32>,
    /// download x0_hat each step? (trajectory analysis only; forces the
    /// reference path — x0_hat only exists host-side)
    record_x0: bool,
    /// latest x0_hat download [B, L*D] (allocated when recording is on)
    last_x0_hat: Vec<f32>,
    out_idx: StepOutIdx,
    /// persistent device buffers for the (immutable) parameters,
    /// uploaded once; §Perf: params never change during generation
    param_bufs: Vec<DeviceTensor>,
    /// artifact-input source table in spec order, resolved at build
    in_src: Vec<Src>,
    /// artifact supports the resident path (format-2 prefix inputs
    /// present AND the kernel opts in)
    resident_capable: bool,
    /// resident path currently enabled (capability-gated switch)
    resident: bool,
    /// previous step's output buffers, device-resident feedback state
    dev_state: Option<DevState>,
    /// host mirrors reflect the latest device state
    state_synced: bool,
    /// per-slot token caches reflect the latest device tokens
    tokens_synced: bool,
    /// slots whose mirror rows were rewritten on the host since the
    /// last upload (reset protocol); folded in on the next step
    dirty: Vec<bool>,
    any_dirty: bool,
    /// on-device prefix clamp rows: mask [B, L], clean state [B, row]
    prefix_mask: Vec<f32>,
    prefix_x: Vec<f32>,
    /// uploaded clamp inputs + the mode they encode (true = real masks
    /// for the resident path, false = all-zero pass-through for the
    /// reference path, which clamps on the host)
    prefix_bufs: Option<(DeviceTensor, DeviceTensor)>,
    prefix_bufs_resident: bool,
    prefix_dirty: bool,
    /// per-step upload slots, reused every device call
    step_up: StepUploads,
    /// a device error swallowed on a best-effort path (lazy token
    /// download in `slot_output`/`release_slot`); surfaced as a hard
    /// error on the next `step()` so a broken device cannot keep
    /// serving silently-stale decodes
    deferred_err: Option<String>,
    /// fused single-sync stat download enabled (effective only on
    /// format-3 artifacts; see [`Session::set_fused_stats`])
    fused_enabled: bool,
    /// per-position token-entropy lane from the last fused step,
    /// `[B, L]` row-major
    tok_entropy: Vec<f32>,
    /// per-position argmax-changed lane (1.0 = argmax differs from the
    /// previous step), `[B, L]` row-major
    tok_changed: Vec<f32>,
    /// the token lanes above reflect the latest executed step (false
    /// after a split-download step and before the first step)
    tok_lanes_fresh: bool,
    /// positions pinned by token-level freeze decisions, `[B, L]`
    /// (1.0 = frozen).  Distinct from `prefix_mask`, which also covers
    /// conditioning prefixes — this lane feeds the wire `frozen_mask`
    /// and the freeze metrics
    frozen: Vec<f32>,
    /// token id pinned at each frozen position, `[B, L]` (forced into
    /// the decode like prefix tokens)
    frozen_vals: Vec<i32>,
    /// per-slot count of freeze-pinned positions
    frozen_counts: Vec<usize>,
    /// reference-path download selection, rebuilt on record_x0 toggles
    want: Vec<usize>,
    /// position of `stats_fused` inside `want` (reference path parses
    /// token lanes out of it so both paths feed policies identically)
    want_fused: Option<usize>,
    /// steps executed (device calls)
    pub device_calls: u64,
}

impl Session {
    /// Create a session bound to the kernel's compiled step artifact
    /// `<artifact_prefix>_step_b<batch>_l<seq_len>`.  Accepts a
    /// built-in [`super::Family`] or any registered [`FamilyId`].
    ///
    /// On format-2 artifacts (on-device prefix-clamp inputs present)
    /// the session starts on the device-resident path; on older
    /// artifacts it transparently serves through the host-roundtrip
    /// reference path.
    pub fn new(
        rt: &Runtime,
        family: impl Into<FamilyId>,
        store: Rc<ParamStore>,
        batch: usize,
        seq_len: usize,
    ) -> Result<Session> {
        let family = family.into();
        let kernel = family.kernel();
        let name =
            format!("{}_step_b{batch}_l{seq_len}", kernel.artifact_prefix());
        let exe = rt.executable(&name)?;
        let m = &rt.manifest.model;
        let (v, d) = (m.vocab, m.d_model);
        let row = kernel.state_row(seq_len, v, d);
        // normalised embeddings (CDCD: rows scaled to sqrt(D))
        let emb = store.get("emb")?.as_f32()?.to_vec();
        if emb.len() != v * d {
            bail!("emb shape mismatch");
        }
        let target = (d as f32).sqrt();
        let mut emb_n = emb;
        for r in 0..v {
            let row_sl = &mut emb_n[r * d..(r + 1) * d];
            let n = row_sl.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-8);
            for x in row_sl.iter_mut() {
                *x *= target / n;
            }
        }
        // upload immutable parameters to persistent device buffers once,
        // and resolve every other input to its per-step data source
        let time_input = kernel.time_input();
        let mut param_bufs = Vec::new();
        let mut in_src = Vec::with_capacity(exe.spec.inputs.len());
        for input in &exe.spec.inputs {
            if let Some(t) = store.tensors.get(&input.name) {
                param_bufs.push(exe.buffer_from_tensor(t)?);
                in_src.push(Src::Param(param_bufs.len() - 1));
            } else {
                let kind = match input.name.as_str() {
                    "x_t" => DataKind::X,
                    "prev_probs" => DataKind::PrevProbs,
                    "prev_tokens" => DataKind::PrevTokens,
                    "z" => DataKind::Z,
                    "prefix_mask" => DataKind::PrefixMask,
                    "prefix_x" => DataKind::PrefixX,
                    n if n == time_input => DataKind::Time,
                    other => bail!("unexpected step input {other}"),
                };
                in_src.push(Src::Data(kind));
            }
        }
        let out_idx = StepOutIdx {
            x_next: exe.spec.output_index("x_next")?,
            probs: exe.spec.output_index("probs")?,
            tokens: exe.spec.output_index("tokens")?,
            entropy: exe.spec.output_index("entropy")?,
            kl: exe.spec.output_index("kl")?,
            switches: exe.spec.output_index("switches")?,
            norm_x0: exe.spec.output_index("norm_x0")?,
            norm_x: exe.spec.output_index("norm_x")?,
            x0_hat: exe.spec.output_index("x0_hat")?,
            stats_fused: exe.spec.output_index("stats_fused").ok(),
        };
        let needs_z = kernel.needs_z();
        let capable = resident_capable(&exe.spec)
            && kernel.supports_device_residency();
        let default_schedule = Schedule::new(family, 1, m.t_max, m.t_min)
            .context("one-step default schedule")?;
        let slots = (0..batch)
            .map(|_| Slot {
                step: 0,
                schedule: default_schedule.clone(),
                active: false,
                rng: Prng::new(0),
                prefix: Vec::new(),
                tokens: vec![0; seq_len],
                last_stats: StepStats::default(),
            })
            .collect();
        let mut s = Session {
            family,
            kernel,
            exe,
            store,
            batch,
            seq_len,
            vocab: v,
            d_model: d,
            row,
            x: vec![0.0; batch * row],
            prev_probs: vec![1.0 / v as f32; batch * seq_len * v],
            prev_tokens: vec![0; batch * seq_len],
            slots,
            emb_n,
            simplex_k: m.simplex_k,
            t2_scratch: vec![0.0; batch * 2],
            z_scratch: if needs_z {
                vec![0.0; batch * row]
            } else {
                Vec::new()
            },
            record_x0: false,
            last_x0_hat: Vec::new(),
            out_idx,
            param_bufs,
            in_src,
            resident_capable: capable,
            resident: capable,
            dev_state: None,
            state_synced: true,
            tokens_synced: true,
            dirty: vec![false; batch],
            any_dirty: false,
            prefix_mask: vec![0.0; batch * seq_len],
            prefix_x: vec![0.0; batch * row],
            prefix_bufs: None,
            prefix_bufs_resident: false,
            prefix_dirty: false,
            step_up: StepUploads::default(),
            deferred_err: None,
            fused_enabled: true,
            tok_entropy: vec![0.0; batch * seq_len],
            tok_changed: vec![0.0; batch * seq_len],
            tok_lanes_fresh: false,
            frozen: vec![0.0; batch * seq_len],
            frozen_vals: vec![0; batch * seq_len],
            frozen_counts: vec![0; batch],
            want: Vec::new(),
            want_fused: None,
            device_calls: 0,
        };
        s.rebuild_want();
        Ok(s)
    }

    /// Occupy a slot with a fresh request: initialise noise, schedule and
    /// optional conditioning prefix.  Fails with a typed [`SlotError`]
    /// (never a panic) on a zero-step budget or an overlong prefix — the
    /// serving path rejects both at admission with `invalid_request`;
    /// this is the backstop for direct library use.
    ///
    /// Resident path: the slot's host-mirror rows are rewritten here and
    /// the slot is marked dirty; the next [`Session::step`] folds the
    /// other slots' device rows in and re-uploads the merged state once
    /// (download-merge-upload only when a reset actually happened).
    pub fn reset_slot(
        &mut self,
        slot: usize,
        req: &SlotRequest,
    ) -> Result<(), SlotError> {
        // validate before mutating anything, so a failed reset leaves
        // the slot exactly as it was
        if req.prefix.len() > self.seq_len {
            return Err(SlotError::PrefixTooLong {
                len: req.prefix.len(),
                max: self.seq_len,
            });
        }
        let schedule =
            Schedule::new(self.family, req.n_steps, req.t_max, req.t_min)?;
        let mut rng = Prng::new(req.seed).fork("gen-noise");
        let sigma = schedule.init_sigma() * req.noise_scale;
        let (l, v) = (self.seq_len, self.vocab);
        let base = slot * self.row;
        self.kernel.init_state(
            &mut self.x[base..base + self.row],
            sigma,
            self.simplex_k,
            &mut rng,
        );
        let pb = slot * l * v;
        for p in &mut self.prev_probs[pb..pb + l * v] {
            *p = 1.0 / v as f32;
        }
        let tb = slot * l;
        for t in &mut self.prev_tokens[tb..tb + l] {
            *t = 0;
        }
        for (i, &tok) in req.prefix.iter().enumerate() {
            self.prev_tokens[tb + i] = tok;
        }
        // rebuild the slot's on-device clamp rows through the SAME
        // helper the host clamp uses, so the two representations are
        // bit-identical by construction.  A prefix-less request
        // replacing a prefix-less occupant leaves the rows untouched —
        // no prefix_dirty, so the (state-sized) clamp buffers are NOT
        // re-uploaded on plain continuous-batching recycles
        let had_prefix =
            self.prefix_mask[tb..tb + l].iter().any(|&m| m != 0.0);
        if had_prefix || !req.prefix.is_empty() {
            self.prefix_mask[tb..tb + l].fill(0.0);
            self.prefix_mask[tb..tb + req.prefix.len()].fill(1.0);
            self.prefix_x[base..base + self.row].fill(0.0);
            clamp_positions(
                self.kernel,
                &mut self.prefix_x[base..base + self.row],
                req.prefix,
                self.row / l,
                v,
                self.d_model,
                &self.emb_n,
                self.simplex_k,
            );
            self.prefix_dirty = true;
        }
        // a frozen occupant implies nonzero mask rows, so the rebuild
        // above already ran and queued the clamp-row re-upload; here
        // only the freeze bookkeeping needs clearing
        if self.frozen_counts[slot] > 0 {
            self.frozen[tb..tb + l].fill(0.0);
            self.frozen_vals[tb..tb + l].fill(0);
            self.frozen_counts[slot] = 0;
        }
        self.dirty[slot] = true;
        self.any_dirty = true;
        let s = &mut self.slots[slot];
        s.step = 0;
        s.schedule = schedule;
        s.active = true;
        s.rng = rng;
        s.prefix = req.prefix.to_vec();
        s.tokens = self.prev_tokens[tb..tb + l].to_vec();
        s.last_stats = StepStats::default();
        self.clamp_prefix(slot);
        Ok(())
    }

    /// Mark a slot free (halted / finished / cancelled).
    ///
    /// Resident path: the slot's decode cache is snapshotted first (one
    /// lazy `[B, L]` token download, skipped when already synced this
    /// step), because an idle slot's *device* state keeps cycling with
    /// neutral times after release — exactly like the reference path,
    /// a released slot's decode stays frozen at its final step.
    pub fn release_slot(&mut self, slot: usize) {
        if let Err(e) = self.sync_tokens() {
            log_warn!(
                "session[{}]: token snapshot at release failed ({e})",
                self.family.name()
            );
            self.deferred_err = Some(format!("{e:#}"));
        }
        self.slots[slot].active = false;
    }

    pub fn any_active(&self) -> bool {
        self.slots.iter().any(|s| s.active)
    }

    /// Detach a live slot's complete generation state for migration to
    /// another session (checkpoint hot-swap drain, or a move to a
    /// right-sized shard).  The device state is folded back into the
    /// host mirrors first ([`Self::adopt_device_state`] — mirrors
    /// become authoritative for the WHOLE batch, so the source
    /// session's next resident step pays one full re-upload; that is
    /// the migration's device cost).  The slot stays active on the
    /// source: callers release it once the export is safely requeued.
    ///
    /// The export is lossless — f32/i32 rows copy bit-for-bit, and the
    /// noise stream moves as the `Prng` itself — which is what makes
    /// migrated generation bit-identical to unmigrated
    /// (`tests/migration_equivalence.rs`).
    pub fn export_slot(&mut self, slot: usize) -> Result<SlotExport> {
        if !self.slots[slot].active {
            bail!("export_slot {slot}: slot is not active");
        }
        self.adopt_device_state()
            .context("export_slot: device state sync")?;
        let (l, v) = (self.seq_len, self.vocab);
        let (base, tb, pb) = (slot * self.row, slot * l, slot * l * v);
        let s = &self.slots[slot];
        Ok(SlotExport {
            family: self.family,
            seq_len: l,
            vocab: v,
            d_model: self.d_model,
            step: s.step,
            schedule: s.schedule.clone(),
            rng: s.rng.clone(),
            prefix: s.prefix.clone(),
            tokens: s.tokens.clone(),
            last_stats: s.last_stats,
            x_row: self.x[base..base + self.row].to_vec(),
            prev_probs_row: self.prev_probs[pb..pb + l * v].to_vec(),
            prev_tokens_row: self.prev_tokens[tb..tb + l].to_vec(),
            prefix_mask_row: self.prefix_mask[tb..tb + l].to_vec(),
            prefix_x_row: self.prefix_x[base..base + self.row].to_vec(),
            frozen_row: self.frozen[tb..tb + l].to_vec(),
            frozen_vals_row: self.frozen_vals[tb..tb + l].to_vec(),
            frozen_count: self.frozen_counts[slot],
        })
    }

    /// Resume an exported slot on this session — the receiving half of
    /// migration.  Requires the same family and compiled seq_len (a
    /// different L is a different compiled graph: attention spans a
    /// different window, so cross-L resumption cannot be bit-exact and
    /// is refused, typed).  Any batch size is fine — that is the point:
    /// a mostly-frozen slot on a b8 shard resumes on a b1 shard.
    ///
    /// Rides the existing mutation protocols end to end: the slot goes
    /// dirty (next resident step folds the other slots' device rows in
    /// and re-uploads the merged state once) and the clamp rows go
    /// `prefix_dirty` (frozen positions re-pin on THIS shard's device
    /// clamp inputs before the first imported step executes).
    pub fn import_slot(&mut self, slot: usize, e: &SlotExport) -> Result<()> {
        if e.family != self.family {
            bail!(
                "import_slot: family mismatch ({} -> {})",
                e.family.name(),
                self.family.name()
            );
        }
        if e.seq_len != self.seq_len
            || e.vocab != self.vocab
            || e.d_model != self.d_model
        {
            bail!(
                "import_slot: shape mismatch (L{}/V{}/D{} -> L{}/V{}/D{})",
                e.seq_len,
                e.vocab,
                e.d_model,
                self.seq_len,
                self.vocab,
                self.d_model
            );
        }
        if self.slots[slot].active {
            bail!("import_slot {slot}: slot is occupied");
        }
        let (l, v) = (self.seq_len, self.vocab);
        let (base, tb, pb) = (slot * self.row, slot * l, slot * l * v);
        self.x[base..base + self.row].copy_from_slice(&e.x_row);
        self.prev_probs[pb..pb + l * v].copy_from_slice(&e.prev_probs_row);
        self.prev_tokens[tb..tb + l].copy_from_slice(&e.prev_tokens_row);
        // clamp rows: re-upload only when either side actually pins
        // positions (same skip rule as reset_slot, so a pin-free
        // migration does not pay the state-sized clamp upload)
        let had_pins =
            self.prefix_mask[tb..tb + l].iter().any(|&m| m != 0.0);
        let has_pins = e.prefix_mask_row.iter().any(|&m| m != 0.0);
        self.prefix_mask[tb..tb + l].copy_from_slice(&e.prefix_mask_row);
        self.prefix_x[base..base + self.row]
            .copy_from_slice(&e.prefix_x_row);
        if had_pins || has_pins {
            self.prefix_dirty = true;
        }
        self.frozen[tb..tb + l].copy_from_slice(&e.frozen_row);
        self.frozen_vals[tb..tb + l].copy_from_slice(&e.frozen_vals_row);
        self.frozen_counts[slot] = e.frozen_count;
        self.dirty[slot] = true;
        self.any_dirty = true;
        let s = &mut self.slots[slot];
        s.step = e.step;
        s.schedule = e.schedule.clone();
        s.active = true;
        s.rng = e.rng.clone();
        s.prefix = e.prefix.clone();
        s.tokens = e.tokens.clone();
        s.last_stats = e.last_stats;
        Ok(())
    }

    /// Drain the deferred best-effort-path device error, if one is
    /// armed (see the `deferred_err` field).  Callers that consume a
    /// lazy decode (e.g. the serving worker after `slot_output` /
    /// `release_slot`) can check here to surface the failure on the
    /// *affected* request instead of failing the whole batch at the
    /// next `step()`.  Draining disarms the step-time bail.
    pub fn take_deferred_err(&mut self) -> Option<String> {
        self.deferred_err.take()
    }

    /// Pin positions of a slot at their current argmax tokens —
    /// token-level early stopping.  Frozen positions join the on-device
    /// clamp rows (`prefix_mask`/`prefix_x`) exactly like a
    /// conditioning prefix: the step graph where-selects them on every
    /// subsequent input and output, so they stop evolving while the
    /// rest of the sequence keeps denoising.  Idempotent per position
    /// (already-pinned positions, prefix or frozen, are skipped);
    /// returns the number of newly frozen positions.
    ///
    /// Resident path: reads the current decode through the lazy token
    /// sync (one `[B, L]` download, shared by every freeze and decode
    /// read this step).  The clamp-row re-upload rides the existing
    /// `prefix_dirty` protocol — paid once on the next step, not per
    /// frozen position.
    pub fn freeze_positions(
        &mut self,
        slot: usize,
        mask: &[bool],
    ) -> Result<usize> {
        self.sync_tokens()?;
        let (l, v, d) = (self.seq_len, self.vocab, self.d_model);
        let w = self.row / l;
        let (tb, xb) = (slot * l, slot * self.row);
        let mut newly = 0;
        for (p, &freeze) in mask.iter().take(l).enumerate() {
            if !freeze || self.prefix_mask[tb + p] > 0.5 {
                continue;
            }
            let tok = self.slots[slot].tokens[p];
            self.frozen[tb + p] = 1.0;
            self.frozen_vals[tb + p] = tok;
            self.frozen_counts[slot] += 1;
            self.prefix_mask[tb + p] = 1.0;
            let t = tok.clamp(0, v as i32 - 1) as usize;
            let s = xb + p * w;
            self.kernel.clamp_token(
                &mut self.prefix_x[s..s + w],
                t,
                &self.emb_n[t * d..(t + 1) * d],
                self.simplex_k,
            );
            // mirror the clamp into the host state row: the reference
            // path uploads it as the next step's input, matching the
            // device path's input-side where-select
            self.x[s..s + w].copy_from_slice(&self.prefix_x[s..s + w]);
            newly += 1;
        }
        if newly > 0 {
            self.prefix_dirty = true;
        }
        Ok(newly)
    }

    /// Every position of a slot pinned (prefix + freezes): nothing can
    /// change anymore, so the worker completes the request with halt
    /// reason `all_frozen` instead of burning further steps.
    pub fn fully_frozen(&self, slot: usize) -> bool {
        let tb = slot * self.seq_len;
        self.prefix_mask[tb..tb + self.seq_len]
            .iter()
            .all(|&m| m > 0.5)
    }

    /// Count of a slot's positions pinned by token-level freezes
    /// (conditioning-prefix positions excluded).
    pub fn frozen_count(&self, slot: usize) -> usize {
        self.frozen_counts[slot]
    }

    /// Fraction of a slot's positions pinned by token-level freezes —
    /// the predictor's completeness feature and the per-family
    /// `frozen_step_fraction` metrics lane.
    pub fn frozen_fraction(&self, slot: usize) -> f32 {
        self.frozen_counts[slot] as f32 / self.seq_len as f32
    }

    /// Which positions of a slot are freeze-pinned — the wire
    /// `frozen_mask` on progress frames.
    pub fn slot_frozen_mask(&self, slot: usize) -> Vec<bool> {
        let tb = slot * self.seq_len;
        self.frozen[tb..tb + self.seq_len]
            .iter()
            .map(|&f| f > 0.5)
            .collect()
    }

    /// Per-position lanes of a slot from the latest step, for
    /// [`crate::halting::HaltPolicy::observe_tokens`]: token entropy,
    /// argmax-changed flags, and the pinned mask (prefix + freezes, so
    /// policies skip already-pinned positions).  `None` when the lanes
    /// are stale (split-download step, format-2 artifact) or the
    /// kernel opts out of token halting — callers then stay on the
    /// scalar `observe` path.
    pub fn slot_token_lanes(
        &self,
        slot: usize,
    ) -> Option<crate::halting::TokenStats<'_>> {
        if !self.tok_lanes_fresh || !self.kernel.supports_token_halting() {
            return None;
        }
        let tb = slot * self.seq_len;
        Some(crate::halting::TokenStats {
            entropy: &self.tok_entropy[tb..tb + self.seq_len],
            changed: &self.tok_changed[tb..tb + self.seq_len],
            frozen: &self.prefix_mask[tb..tb + self.seq_len],
        })
    }

    /// Overwrite prefix positions of the host mirror with their clean
    /// representation — replacement conditioning, matching how
    /// prefix-masked training kept unmasked positions clean at every
    /// noise level.  The per-family representation (embedding row vs ±K
    /// logits) is the kernel's.  Split-borrows the slot: no per-call
    /// clone of the prefix (§Perf).
    fn clamp_prefix(&mut self, slot: usize) {
        let (v, d) = (self.vocab, self.d_model);
        let simplex_k = self.simplex_k;
        let w = self.row / self.seq_len;
        let base = slot * self.row;
        let row = self.row;
        let kernel = self.kernel;
        let Self { slots, x, emb_n, .. } = self;
        clamp_positions(
            kernel,
            &mut x[base..base + row],
            &slots[slot].prefix,
            w,
            v,
            d,
            emb_n,
            simplex_k,
        );
    }

    /// Enable/disable the per-step `x0_hat` download (Fig-2 trajectory
    /// analysis).  Recording forces the host-roundtrip reference path —
    /// `x0_hat` only exists host-side — so any device-resident state is
    /// folded back into the host mirrors first.
    pub fn set_record_x0(&mut self, on: bool) -> Result<()> {
        if on {
            self.adopt_device_state()?;
        }
        self.record_x0 = on;
        if on && self.last_x0_hat.is_empty() {
            self.last_x0_hat =
                vec![0.0; self.batch * self.seq_len * self.d_model];
        }
        self.rebuild_want();
        Ok(())
    }

    /// Switch the device-resident path on or off; returns the effective
    /// state (enabling is capability-gated: format-2 artifact + kernel
    /// opt-in).  Disabling folds the device state back into the host
    /// mirrors, so the reference path continues bit-identically.
    pub fn set_resident(&mut self, on: bool) -> Result<bool> {
        if on {
            self.resident = self.resident_capable;
        } else {
            self.adopt_device_state()?;
            self.resident = false;
        }
        Ok(self.resident)
    }

    /// Is the device-resident path currently enabled?
    pub fn resident(&self) -> bool {
        self.resident
    }

    /// Could this session go resident at all (format-2 artifact whose
    /// kernel supports residency)?
    pub fn resident_supported(&self) -> bool {
        self.resident_capable
    }

    fn rebuild_want(&mut self) {
        let o = &self.out_idx;
        self.want.clear();
        self.want.extend([
            o.x_next, o.probs, o.tokens, o.entropy, o.kl, o.switches,
            o.norm_x0, o.norm_x,
        ]);
        if self.record_x0 {
            self.want.push(o.x0_hat);
        }
        // token lanes ride along on the reference path too, so the
        // halting policies observe the same signals on both paths
        self.want_fused = match o.stats_fused {
            Some(fi) if self.fused_enabled => {
                self.want.push(fi);
                Some(self.want.len() - 1)
            }
            _ => None,
        };
    }

    /// Enable/disable the fused single-sync stat download (effective
    /// only on format-3 artifacts); returns the effective state.
    /// Disabled, the resident step falls back to the five-row split
    /// download and the token lanes stop refreshing, so token-level
    /// halting becomes unavailable — `hotpath_micro`'s fused-vs-split
    /// row and the legacy byte-budget test drive this switch.
    pub fn set_fused_stats(&mut self, on: bool) -> bool {
        self.fused_enabled = on;
        self.rebuild_want();
        self.fused_active()
    }

    /// Is the fused stat download in effect (format-3 artifact AND
    /// enabled)?
    pub fn fused_active(&self) -> bool {
        self.fused_enabled && self.out_idx.stats_fused.is_some()
    }

    /// Can this session expose per-position token lanes (fused stats
    /// in effect AND the kernel opts into token halting)?
    pub fn token_halting_available(&self) -> bool {
        self.fused_active() && self.kernel.supports_token_halting()
    }

    /// Fold the device-resident state back into the host mirrors and
    /// drop the device copies; the mirrors become authoritative.  Rows
    /// of dirty slots are NOT overwritten — their mirrors already hold
    /// a fresh reset that the device has never seen.
    fn adopt_device_state(&mut self) -> Result<()> {
        let Some(ds) = self.dev_state.take() else {
            self.state_synced = true;
            return Ok(());
        };
        if !self.state_synced {
            let x = self.exe.download_output(&ds.x)?;
            let probs = self.exe.download_output(&ds.probs)?;
            let tokens = self.exe.download_output(&ds.tokens)?;
            let (xs, ps, ts) = (x.as_f32()?, probs.as_f32()?, tokens.as_i32()?);
            let (l, v, row) = (self.seq_len, self.vocab, self.row);
            for i in 0..self.batch {
                if self.dirty[i] {
                    continue;
                }
                self.x[i * row..(i + 1) * row]
                    .copy_from_slice(&xs[i * row..(i + 1) * row]);
                self.prev_probs[i * l * v..(i + 1) * l * v]
                    .copy_from_slice(&ps[i * l * v..(i + 1) * l * v]);
                self.prev_tokens[i * l..(i + 1) * l]
                    .copy_from_slice(&ts[i * l..(i + 1) * l]);
                // decode caches refresh for live slots only — a
                // released slot keeps its final-step snapshot (the
                // device row idled on after release)
                let slot = &mut self.slots[i];
                if slot.active {
                    slot.tokens.copy_from_slice(&ts[i * l..(i + 1) * l]);
                }
            }
        }
        self.state_synced = true;
        self.tokens_synced = true;
        Ok(())
    }

    /// Refresh the per-slot token caches from the device (one `[B,L]`
    /// i32 download), if they are stale.  No-op on the reference path.
    fn sync_tokens(&mut self) -> Result<()> {
        if self.tokens_synced {
            return Ok(());
        }
        let Some(ds) = &self.dev_state else {
            self.tokens_synced = true;
            return Ok(());
        };
        let t = self.exe.download_output(&ds.tokens)?;
        let toks = t.as_i32()?;
        let l = self.seq_len;
        for (i, s) in self.slots.iter_mut().enumerate() {
            // only live slots refresh: a dirty slot was reset after the
            // last device step (its cache already holds the fresh
            // reset), and a released slot's cache stays frozen at its
            // final decode — the device row has moved on with idle
            // times since (matching reference-path commit semantics)
            if s.active && !self.dirty[i] {
                s.tokens.copy_from_slice(&toks[i * l..(i + 1) * l]);
            }
        }
        self.tokens_synced = true;
        Ok(())
    }

    /// Ensure the prefix-clamp input buffers match `resident_mode`:
    /// real per-slot masks for the resident path (device clamps), an
    /// all-zero pass-through for the reference path (host clamps —
    /// byte-identical legacy behaviour).  Re-uploads only on resets and
    /// mode switches, never per step.
    fn ensure_prefix_bufs(&mut self, resident_mode: bool) -> Result<()> {
        if !self.resident_capable && resident_mode {
            bail!("resident step on a non-capable artifact");
        }
        if !self.exe.spec.has_input("prefix_mask") {
            return Ok(()); // format-1 artifact: no clamp inputs at all
        }
        let fresh = self.prefix_bufs.is_some()
            && self.prefix_bufs_resident == resident_mode
            && !(resident_mode && self.prefix_dirty);
        if fresh {
            return Ok(());
        }
        let (b, l) = (self.batch, self.seq_len);
        let w = self.row / l;
        let bufs = if resident_mode {
            (
                self.exe.buffer_from_f32(&[b, l], &self.prefix_mask)?,
                self.exe.buffer_from_f32(&[b, l, w], &self.prefix_x)?,
            )
        } else {
            let zero_mask = vec![0.0f32; b * l];
            let zero_x = vec![0.0f32; b * self.row];
            (
                self.exe.buffer_from_f32(&[b, l], &zero_mask)?,
                self.exe.buffer_from_f32(&[b, l, w], &zero_x)?,
            )
        };
        self.prefix_bufs = Some(bufs);
        self.prefix_bufs_resident = resident_mode;
        if resident_mode {
            self.prefix_dirty = false;
        }
        Ok(())
    }

    /// Fill the per-slot (t_cur, t_next) scratch and refresh noise for
    /// active slots (idle slots keep neutral times / stale noise; their
    /// outputs are ignored) — shared by both step paths.
    fn prepare_times_and_noise(&mut self) {
        let idle = self.kernel.idle_times();
        for (i, s) in self.slots.iter().enumerate() {
            let (c, n) = if s.active && s.step < s.schedule.n_steps() {
                s.schedule.pair(s.step)
            } else {
                idle
            };
            self.t2_scratch[i * 2] = c;
            self.t2_scratch[i * 2 + 1] = n;
        }
        if self.kernel.needs_z() {
            let row = self.row;
            let z = &mut self.z_scratch;
            for (i, s) in self.slots.iter_mut().enumerate() {
                if s.active {
                    s.rng.fill_gaussian_f32(&mut z[i * row..(i + 1) * row]);
                }
            }
        }
    }

    /// Advance every active slot by one diffusion step (one device call).
    /// Inactive slots are stepped with neutral times and ignored.
    /// Returns per-slot stats for slots that were active.
    pub fn step(&mut self) -> Result<Vec<Option<StepStats>>> {
        // a device error swallowed by a best-effort token download must
        // not stay silent: fail the next step through the caller's
        // normal device-failure path
        if let Some(e) = self.deferred_err.take() {
            bail!("deferred device failure: {e}");
        }
        self.prepare_times_and_noise();
        if self.resident && !self.record_x0 {
            match self.step_resident() {
                Err(e) if e.downcast_ref::<TupleNotDecomposed>().is_some() =>
                {
                    // can only fire on the first resident execution (the
                    // output layout is a property of the runtime), and
                    // the resident path commits nothing before it — the
                    // host mirrors are still authoritative, so the
                    // reference path continues losslessly.  The probe
                    // execution is discarded (one extra device call +
                    // ExecStats execution, once per session lifetime)
                    log_warn!(
                        "session[{}]: {e}; downgrading to the \
                         host-roundtrip path",
                        self.family.name()
                    );
                    self.resident = false;
                    self.dev_state = None;
                    self.step_reference()
                }
                out => out,
            }
        } else {
            self.step_reference()
        }
    }

    /// One device-resident step: feed back the previous step's output
    /// buffers, upload only times (+ noise), download only the `[B]`
    /// stat rows.
    fn step_resident(&mut self) -> Result<Vec<Option<StepStats>>> {
        let exe = self.exe.clone();
        let (b, l, v) = (self.batch, self.seq_len, self.vocab);
        // dirty protocol: fold the device rows of non-dirty slots into
        // the mirrors, then re-upload the merged state once below
        if self.any_dirty {
            self.adopt_device_state()?;
            self.dirty.fill(false);
            self.any_dirty = false;
        }
        self.ensure_prefix_bufs(true)?;
        let x_shape = self.kernel.x_shape(b, l, v, self.d_model);
        self.step_up.time =
            Some(exe.buffer_from_f32(&[b, 2], &self.t2_scratch)?);
        if self.kernel.needs_z() {
            self.step_up.z =
                Some(exe.buffer_from_f32(&x_shape, &self.z_scratch)?);
        }
        if self.dev_state.is_none() {
            // first step after build / reset-sync / mode switch: the
            // state enters the device from the host mirrors once
            self.step_up.x =
                Some(exe.buffer_from_f32(&x_shape, &self.x)?);
            self.step_up.prev_probs =
                Some(exe.buffer_from_f32(&[b, l, v], &self.prev_probs)?);
            self.step_up.prev_tokens =
                Some(exe.buffer_from_i32(&[b, l], &self.prev_tokens)?);
        } else {
            self.step_up.x = None;
            self.step_up.prev_probs = None;
            self.step_up.prev_tokens = None;
        }

        let refs = build_refs(
            &self.in_src,
            &self.param_bufs,
            &self.step_up,
            self.dev_state.as_ref(),
            self.prefix_bufs.as_ref(),
        )?;
        let outs = exe.run_buffers_device(&refs).context("step execute")?;
        drop(refs);
        self.device_calls += 1;

        // the only steady-state download.  Format-3 artifacts: ONE
        // fused [B, 5+2L] stat tensor — a single device→host sync per
        // step — de-strided on the host into the five scalar rows plus
        // the per-position token lanes.  Format-2 fallback (or fused
        // stats disabled): the five [B] stat rows split across five
        // syncs, token lanes unavailable.
        let o_fused = if self.fused_enabled {
            self.out_idx.stats_fused
        } else {
            None
        };
        let (ent_v, kl_v, sw_v, n0_v, nx_v);
        if let Some(fi) = o_fused {
            let fused = exe.download_output(&outs[fi])?;
            let f = fused.as_f32()?;
            let w = 5 + 2 * l;
            let mut e = vec![0.0f32; b];
            let mut k = vec![0.0f32; b];
            let mut s = vec![0.0f32; b];
            let mut n0 = vec![0.0f32; b];
            let mut nx = vec![0.0f32; b];
            for i in 0..b {
                let r = i * w;
                e[i] = f[r];
                k[i] = f[r + 1];
                s[i] = f[r + 2];
                n0[i] = f[r + 3];
                nx[i] = f[r + 4];
                self.tok_entropy[i * l..(i + 1) * l]
                    .copy_from_slice(&f[r + 5..r + 5 + l]);
                self.tok_changed[i * l..(i + 1) * l]
                    .copy_from_slice(&f[r + 5 + l..r + 5 + 2 * l]);
            }
            (ent_v, kl_v, sw_v, n0_v, nx_v) = (e, k, s, n0, nx);
            self.tok_lanes_fresh = true;
        } else {
            let o = &self.out_idx;
            ent_v = exe.download_output(&outs[o.entropy])?.as_f32()?.to_vec();
            kl_v = exe.download_output(&outs[o.kl])?.as_f32()?.to_vec();
            sw_v = exe.download_output(&outs[o.switches])?.as_f32()?.to_vec();
            n0_v = exe.download_output(&outs[o.norm_x0])?.as_f32()?.to_vec();
            nx_v = exe.download_output(&outs[o.norm_x])?.as_f32()?.to_vec();
            self.tok_lanes_fresh = false;
        }
        let step_out = StepOutputs {
            entropy: &ent_v,
            kl: &kl_v,
            switches: &sw_v,
            norm_x0: &n0_v,
            norm_x: &nx_v,
        };
        let mut results = Vec::with_capacity(b);
        for i in 0..b {
            if !self.slots[i].active {
                results.push(None);
                continue;
            }
            let stats = self.kernel.parse_stats(i, &step_out);
            let slot = &mut self.slots[i];
            slot.last_stats = stats;
            slot.step += 1;
            results.push(Some(stats));
        }
        // the bulky outputs stay on the device, becoming the next
        // step's inputs; decoded tokens download lazily (slot_output).
        // Buffer lifetime: the stat downloads above forced this
        // execution to complete, so dropping the previous step's
        // feedback buffers (the old dev_state, replaced here) and this
        // step's one-off uploads (overwritten next call) is safe even
        // under an asynchronous PJRT execute.
        let mut outs: Vec<Option<xla::PjRtBuffer>> =
            outs.into_iter().map(Some).collect();
        let mut take = |i: usize| {
            // lint:allow(panic-freedom): each index is taken exactly once
            outs[i].take().expect("step output consumed twice")
        };
        let o = &self.out_idx;
        self.dev_state = Some(DevState {
            x: take(o.x_next),
            probs: take(o.probs),
            tokens: take(o.tokens),
        });
        self.state_synced = false;
        self.tokens_synced = false;
        Ok(results)
    }

    /// One host-roundtrip step — the reference path: every output
    /// materialises into the host mirrors (pre-resident behaviour, and
    /// the baseline the equivalence tests pin the resident path to).
    fn step_reference(&mut self) -> Result<Vec<Option<StepStats>>> {
        let exe = self.exe.clone();
        let (b, l, v) = (self.batch, self.seq_len, self.vocab);
        // a mode switch may leave device state adrift; fold it back in
        // so the mirrors are authoritative (no-op otherwise)
        self.adopt_device_state()?;
        self.dirty.fill(false);
        self.any_dirty = false;
        self.ensure_prefix_bufs(false)?;
        let x_shape = self.kernel.x_shape(b, l, v, self.d_model);
        self.step_up.x = Some(exe.buffer_from_f32(&x_shape, &self.x)?);
        self.step_up.prev_probs =
            Some(exe.buffer_from_f32(&[b, l, v], &self.prev_probs)?);
        self.step_up.prev_tokens =
            Some(exe.buffer_from_i32(&[b, l], &self.prev_tokens)?);
        self.step_up.time =
            Some(exe.buffer_from_f32(&[b, 2], &self.t2_scratch)?);
        if self.kernel.needs_z() {
            self.step_up.z =
                Some(exe.buffer_from_f32(&x_shape, &self.z_scratch)?);
        }

        let refs = build_refs(
            &self.in_src,
            &self.param_bufs,
            &self.step_up,
            None,
            self.prefix_bufs.as_ref(),
        )?;
        let out_lits = exe.run_buffers(&refs).context("step execute")?;
        drop(refs);
        self.device_calls += 1;

        // convert only what the caller reads; x0_hat converts lazily
        let out = exe.download_selected(&out_lits, &self.want)?;
        let x_next = out[0].as_f32()?;
        let probs = out[1].as_f32()?;
        let tokens = out[2].as_i32()?;
        let step_out = StepOutputs {
            entropy: out[3].as_f32()?,
            kl: out[4].as_f32()?,
            switches: out[5].as_f32()?,
            norm_x0: out[6].as_f32()?,
            norm_x: out[7].as_f32()?,
        };
        let x0_hat = if self.record_x0 {
            Some(out[8].as_f32()?)
        } else {
            None
        };
        // token lanes from the fused tensor (already materialised by
        // run_buffers — no extra sync on this path), so policies see
        // the same per-position signals as on the resident path
        if let Some(wf) = self.want_fused {
            let f = out[wf].as_f32()?;
            let w = 5 + 2 * l;
            for i in 0..b {
                let r = i * w + 5;
                self.tok_entropy[i * l..(i + 1) * l]
                    .copy_from_slice(&f[r..r + l]);
                self.tok_changed[i * l..(i + 1) * l]
                    .copy_from_slice(&f[r + l..r + 2 * l]);
            }
            self.tok_lanes_fresh = true;
        } else {
            self.tok_lanes_fresh = false;
        }

        let mut results = Vec::with_capacity(b);
        for i in 0..b {
            if !self.slots[i].active {
                results.push(None);
                continue;
            }
            // commit state for this slot
            let xb = i * self.row;
            self.x[xb..xb + self.row]
                .copy_from_slice(&x_next[xb..xb + self.row]);
            let pb = i * l * v;
            self.prev_probs[pb..pb + l * v]
                .copy_from_slice(&probs[pb..pb + l * v]);
            let tb = i * l;
            self.prev_tokens[tb..tb + l]
                .copy_from_slice(&tokens[tb..tb + l]);
            if let Some(x0) = x0_hat {
                let w = l * self.d_model;
                self.last_x0_hat[i * w..(i + 1) * w]
                    .copy_from_slice(&x0[i * w..(i + 1) * w]);
            }
            let stats = self.kernel.parse_stats(i, &step_out);
            let slot = &mut self.slots[i];
            slot.tokens.copy_from_slice(&tokens[tb..tb + l]);
            slot.last_stats = stats;
            slot.step += 1;
            results.push(Some(stats));
        }
        // re-clamp pinned positions (conditioning prefix + token-level
        // freezes) after the state update by copying the precomputed
        // clean rows out of `prefix_x` — the exact host image of the
        // device path's `where(mask, prefix_x, x)` output clamp, and
        // bit-identical to the legacy per-token re-clamp (both write
        // the same `clamp_positions` rows)
        let w = self.row / l;
        for i in 0..b {
            if !self.slots[i].active {
                continue;
            }
            let mb = i * l;
            for p in 0..l {
                if self.prefix_mask[mb + p] > 0.5 {
                    let s = i * self.row + p * w;
                    self.x[s..s + w]
                        .copy_from_slice(&self.prefix_x[s..s + w]);
                }
            }
        }
        self.state_synced = true;
        self.tokens_synced = true;
        Ok(results)
    }

    /// Current diffusion-state row of a slot (kernel-defined width: L*D
    /// for embedding families, L*V for simplex) — used by the Fig-2
    /// trajectory analysis, which runs on the reference path
    /// ([`Self::set_record_x0`]); asserts the host mirror is current.
    pub fn slot_x(&self, slot: usize) -> &[f32] {
        assert!(
            self.state_synced,
            "slot_x on stale host mirrors — the device-resident path \
             does not maintain them; use set_record_x0/set_resident(false)"
        );
        &self.x[slot * self.row..(slot + 1) * self.row]
    }

    /// Latest x0_hat row of a slot (always L*D) — Fig-2 score analysis.
    /// Requires [`Self::set_record_x0`]`(true)` before stepping.
    pub fn slot_x0_hat(&self, slot: usize) -> &[f32] {
        assert!(
            self.record_x0,
            "x0_hat recording is off — call set_record_x0(true) first"
        );
        let w = self.seq_len * self.d_model;
        &self.last_x0_hat[slot * w..(slot + 1) * w]
    }

    /// Decoded tokens of a slot (prefix positions forced to the prefix).
    /// On the resident path this triggers the lazy `[B, L]` token
    /// download (once per step, shared by every slot read); a failed
    /// download degrades to the last synced decode with a warning AND
    /// arms a deferred error, so the next `step()` fails through the
    /// caller's normal device-failure path instead of the session
    /// silently serving stale decodes.
    pub fn slot_output(&mut self, slot: usize) -> Vec<i32> {
        if let Err(e) = self.sync_tokens() {
            log_warn!(
                "session[{}]: token download failed ({e}); serving the \
                 last synced decode",
                self.family.name()
            );
            self.deferred_err = Some(format!("{e:#}"));
        }
        let s = &self.slots[slot];
        let mut out = s.tokens.clone();
        for (i, &t) in s.prefix.iter().enumerate() {
            out[i] = t;
        }
        // freeze-pinned positions are forced like prefix positions: the
        // decode commits to the token captured at freeze time, not to
        // whatever the clamped state's argmax drifts to afterwards
        let tb = slot * self.seq_len;
        for (p, o) in out.iter_mut().enumerate() {
            if self.frozen[tb + p] > 0.5 {
                *o = self.frozen_vals[tb + p];
            }
        }
        out
    }

    /// True when a slot has exhausted its schedule.
    pub fn slot_exhausted(&self, slot: usize) -> bool {
        let s = &self.slots[slot];
        s.step >= s.schedule.n_steps()
    }

    /// Hot-loop accounting (per-call stats live on the executable).
    pub fn exec_stats(&self) -> crate::runtime::ExecStats {
        self.exe.stats()
    }
}

/// Write each prefix token's clean per-family representation into its
/// position of one state row (`dst` = the slot's `[row]` slice, `w` =
/// per-position width).  The ONE addressing + `clamp_token` call both
/// the host clamp (`clamp_prefix` → mirror `x`) and the on-device
/// clamp rows (`reset_slot` → `prefix_x`) go through — keeping the two
/// representations bit-identical by construction, which the resident /
/// reference equivalence depends on.
#[allow(clippy::too_many_arguments)]
fn clamp_positions(
    kernel: &dyn FamilyKernel,
    dst: &mut [f32],
    prefix: &[i32],
    w: usize,
    v: usize,
    d: usize,
    emb_n: &[f32],
    simplex_k: f32,
) {
    for (pos, &tok) in prefix.iter().enumerate() {
        let tok = tok.clamp(0, v as i32 - 1) as usize;
        kernel.clamp_token(
            &mut dst[pos * w..(pos + 1) * w],
            tok,
            &emb_n[tok * d..(tok + 1) * d],
            simplex_k,
        );
    }
}

/// Assemble the artifact's input table in spec order.  The exact-sized
/// pointer `Vec` is the hot loop's one remaining per-step allocation:
/// it holds borrows of buffers owned by `self`, so it cannot live in
/// persistent scratch without `unsafe` — and at one machine word per
/// input it is noise next to the execute itself.
fn build_refs<'a>(
    in_src: &[Src],
    param_bufs: &'a [DeviceTensor],
    step_up: &'a StepUploads,
    dev_state: Option<&'a DevState>,
    prefix_bufs: Option<&'a (DeviceTensor, DeviceTensor)>,
) -> Result<Vec<&'a xla::PjRtBuffer>> {
    let mut refs = Vec::with_capacity(in_src.len());
    for src in in_src {
        let buf: &xla::PjRtBuffer = match src {
            Src::Param(k) => &param_bufs[*k].buf,
            Src::Data(kind) => match kind {
                DataKind::X => match (&step_up.x, dev_state) {
                    (Some(up), _) => &up.buf,
                    (None, Some(ds)) => &ds.x,
                    (None, None) => bail!("x_t input has no source"),
                },
                DataKind::PrevProbs => {
                    match (&step_up.prev_probs, dev_state) {
                        (Some(up), _) => &up.buf,
                        (None, Some(ds)) => &ds.probs,
                        (None, None) => {
                            bail!("prev_probs input has no source")
                        }
                    }
                }
                DataKind::PrevTokens => {
                    match (&step_up.prev_tokens, dev_state) {
                        (Some(up), _) => &up.buf,
                        (None, Some(ds)) => &ds.tokens,
                        (None, None) => {
                            bail!("prev_tokens input has no source")
                        }
                    }
                }
                DataKind::Z => match &step_up.z {
                    Some(up) => &up.buf,
                    None => bail!("z input has no source"),
                },
                DataKind::Time => match &step_up.time {
                    Some(up) => &up.buf,
                    None => bail!("time input has no source"),
                },
                DataKind::PrefixMask => match prefix_bufs {
                    Some((mask, _)) => &mask.buf,
                    None => bail!("prefix_mask input has no source"),
                },
                DataKind::PrefixX => match prefix_bufs {
                    Some((_, px)) => &px.buf,
                    None => bail!("prefix_x input has no source"),
                },
            },
        };
        refs.push(buf);
    }
    Ok(refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, InputSpec};

    fn spec_with_inputs(names: &[&str]) -> ArtifactSpec {
        ArtifactSpec {
            name: "ddlm_step_b1_l64".into(),
            file: "ddlm_step_b1_l64.hlo.txt".into(),
            family: "ddlm".into(),
            role: "step".into(),
            batch: 1,
            seq_len: 64,
            inputs: names
                .iter()
                .map(|n| InputSpec {
                    name: n.to_string(),
                    shape: vec![1],
                    dtype: Dtype::F32,
                })
                .collect(),
            outputs: vec!["x_next".into()],
        }
    }

    #[test]
    fn residency_capability_is_probed_per_artifact() {
        // format-2 step artifacts carry both clamp inputs
        let v2 = spec_with_inputs(&[
            "x_t", "prev_probs", "prev_tokens", "t2", "prefix_mask",
            "prefix_x",
        ]);
        assert!(resident_capable(&v2));
        // format-1 artifacts (or a partially pruned one) are not
        // resident-capable: sessions fall back to the reference path
        let v1 =
            spec_with_inputs(&["x_t", "prev_probs", "prev_tokens", "t2"]);
        assert!(!resident_capable(&v1));
        let half = spec_with_inputs(&[
            "x_t", "prev_probs", "prev_tokens", "t2", "prefix_mask",
        ]);
        assert!(!resident_capable(&half));
    }
}
